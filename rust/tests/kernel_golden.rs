//! Host-kernel ↔ executable drift guard, hermetic on fixture artifacts:
//! the executable's `x_prev` must match the host-side Eq.-12 arithmetic
//! (`ddim_update_host` / `ddim_update_host_sigma`) lane by lane — padding
//! lanes included — for every noise mode the serving path accepts (η=0,
//! η=1, σ̂). And the host-integrated kernels (PF-ODE Euler per Eq. 15, AB2
//! per §7) must commit exactly what `pf_euler_update` / `Ab2State` compute
//! from the executable's ε output. This single file is what keeps *all*
//! update kernels and the step backend from drifting apart silently, on
//! whichever backend the runtime loads.
//!
//! Inputs are packed through the shared `StepBatch` (the exact serving
//! path), then read back via `StepBatch::packed` so the comparison uses
//! precisely what the executable saw.

use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::{
    ddim_update_host, ddim_update_host_sigma, pf_euler_update, Ab2State, SamplerKind, StepBatch,
    Trajectory,
};
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::testing::fixtures;

#[test]
fn executable_x_prev_matches_host_ddim_update_across_modes() {
    let mut rt = Runtime::load(fixtures::root()).unwrap();
    let dim = rt.manifest().sample_dim();
    let bucket = rt.manifest().bucket_for(4);
    let abar = rt.alphas().clone();
    let real_lanes = 2usize.min(bucket);

    for mode in [NoiseMode::Eta(0.0), NoiseMode::Eta(1.0), NoiseMode::SigmaHat] {
        let plan = SamplePlan::generate(&abar, TauKind::Linear, 5, mode).unwrap();
        let mut trajs: Vec<Trajectory> = (0..real_lanes)
            .map(|i| Trajectory::from_prior(plan.clone(), dim, 1000 + i as u64))
            .collect();
        let mut batch = StepBatch::new(bucket, dim);
        for step in 0..plan.len() {
            for (slot, tr) in trajs.iter_mut().enumerate() {
                batch.pack(slot, tr).unwrap();
            }
            batch.pad(real_lanes, bucket);
            // run through a fresh executable handle each step (cache hit)
            let exe = rt.executable("sprites", bucket).unwrap();
            batch.run(exe, bucket).unwrap();

            // every lane — real and padding — must satisfy the host Eq.-12
            // composition on the inputs it was actually packed with
            for slot in 0..bucket {
                let packed = batch.packed(slot);
                let out = batch.lane(slot);
                let want = ddim_update_host_sigma(
                    packed.x,
                    out.eps,
                    packed.noise,
                    packed.alpha_in as f64,
                    packed.alpha_out as f64,
                    packed.sigma as f64,
                );
                let max = out
                    .x_prev
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    max < 2e-4,
                    "{} step {step} lane {slot} (padding={}): \
                     executable x_prev drifted {max} from host Eq. 12",
                    mode.label(),
                    slot >= real_lanes
                );
                // deterministic lanes must also match the σ=0 fast form
                if packed.sigma == 0.0 && packed.noise.iter().all(|&n| n == 0.0) {
                    let det = ddim_update_host(
                        packed.x,
                        out.eps,
                        packed.alpha_in as f64,
                        packed.alpha_out as f64,
                    );
                    let max = out
                        .x_prev
                        .iter()
                        .zip(&det)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(max < 2e-4, "{} deterministic form drift {max}", mode.label());
                }
            }
            for (slot, tr) in trajs.iter_mut().enumerate() {
                tr.advance(batch.lane(slot)).unwrap();
            }
        }
        assert!(trajs.iter().all(|t| t.is_done()));
    }
}

/// The host-integrated kernels, pinned lane by lane through the full
/// serving path: a PF-ODE lane's committed state must equal
/// `pf_euler_update` on the executable's ε, and an AB2 lane must equal a
/// reference `Ab2State` driven over the same (ε, ᾱ) sequence — padded
/// slots present throughout, η=0 (the only plans these kernels accept).
#[test]
fn host_kernels_match_their_references_through_step_batch() {
    let mut rt = Runtime::load(fixtures::root()).unwrap();
    let dim = rt.manifest().sample_dim();
    let bucket = rt.manifest().bucket_for(4);
    let abar = rt.alphas().clone();
    let plan = SamplePlan::generate(&abar, TauKind::Linear, 6, NoiseMode::Eta(0.0)).unwrap();

    // lane 0: PF-ODE, lane 1: AB2 — heterogeneous kernels in one batch
    let mut trajs = vec![
        Trajectory::from_prior_with(plan.clone(), dim, 501, SamplerKind::PfOde),
        Trajectory::from_prior_with(plan.clone(), dim, 502, SamplerKind::Ab2),
    ];
    let mut pf_state = trajs[0].state().to_vec();
    let mut ab_state = trajs[1].state().to_vec();
    let mut ab_ref = Ab2State::new();

    let mut batch = StepBatch::new(bucket, dim);
    for (step, params) in plan.steps().iter().enumerate() {
        for (slot, tr) in trajs.iter_mut().enumerate() {
            batch.pack(slot, tr).unwrap();
        }
        batch.pad(trajs.len(), bucket);
        let exe = rt.executable("sprites", bucket).unwrap();
        batch.run(exe, bucket).unwrap();

        // host references computed from the executable's own ε readback
        pf_state = pf_euler_update(
            &pf_state,
            batch.lane(0).eps,
            params.alpha_in,
            params.alpha_out,
        );
        ab_ref.step_inplace(&mut ab_state, batch.lane(1).eps, params.alpha_in, params.alpha_out);

        for (slot, tr) in trajs.iter_mut().enumerate() {
            tr.advance(batch.lane(slot)).unwrap();
        }
        assert_eq!(
            trajs[0].state(),
            &pf_state[..],
            "step {step}: PF-ODE lane drifted from pf_euler_update"
        );
        assert_eq!(
            trajs[1].state(),
            &ab_state[..],
            "step {step}: AB2 lane drifted from the reference Ab2State"
        );
    }
    assert!(trajs.iter().all(|t| t.is_done()));
    // the two kernels start from different priors AND integrate
    // differently; identical results would mean a wiring bug
    assert_ne!(trajs[0].state(), trajs[1].state());
}
