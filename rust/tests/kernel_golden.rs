//! Host-kernel ↔ AOT-graph drift guard: the fused executable's `x_prev`
//! must match the host-side Eq.-12 arithmetic (`ddim_update_host` /
//! `ddim_update_host_sigma`) lane by lane — padding lanes included — for
//! every noise mode the serving path accepts (η=0, η=1, σ̂). The engine's
//! PF-ODE/AB2 kernels re-integrate from the same executable's ε, so this
//! single invariant is what keeps *all* update kernels and the compiled
//! graph from drifting apart silently.
//!
//! Inputs are packed through the shared `StepBatch` (the exact serving
//! path), then read back via `StepBatch::packed` so the comparison uses
//! precisely what the executable saw.

use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::{ddim_update_host, ddim_update_host_sigma, StepBatch, Trajectory};
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};

const ROOT: &str = env!("CARGO_MANIFEST_DIR");

fn artifacts_root() -> String {
    format!("{ROOT}/artifacts")
}

#[test]
fn executable_x_prev_matches_host_ddim_update_across_modes() {
    let root = artifacts_root();
    if !std::path::Path::new(&root).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::load(&root).unwrap();
    let dim = rt.manifest().sample_dim();
    let bucket = rt.manifest().bucket_for(4);
    let abar = rt.alphas().clone();
    let real_lanes = 2usize.min(bucket);

    for mode in [NoiseMode::Eta(0.0), NoiseMode::Eta(1.0), NoiseMode::SigmaHat] {
        let plan = SamplePlan::generate(&abar, TauKind::Linear, 5, mode).unwrap();
        let mut trajs: Vec<Trajectory> = (0..real_lanes)
            .map(|i| Trajectory::from_prior(plan.clone(), dim, 1000 + i as u64))
            .collect();
        let mut batch = StepBatch::new(bucket, dim);
        for step in 0..plan.len() {
            for (slot, tr) in trajs.iter_mut().enumerate() {
                batch.pack(slot, tr).unwrap();
            }
            batch.pad(real_lanes, bucket);
            // run through a fresh executable handle each step (cache hit)
            let exe = rt.executable("sprites", bucket).unwrap();
            batch.run(exe, bucket).unwrap();

            // every lane — real and padding — must satisfy the host Eq.-12
            // composition on the inputs it was actually packed with
            for slot in 0..bucket {
                let packed = batch.packed(slot);
                let out = batch.lane(slot);
                let want = ddim_update_host_sigma(
                    packed.x,
                    out.eps,
                    packed.noise,
                    packed.alpha_in as f64,
                    packed.alpha_out as f64,
                    packed.sigma as f64,
                );
                let max = out
                    .x_prev
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    max < 2e-4,
                    "{} step {step} lane {slot} (padding={}): \
                     executable x_prev drifted {max} from host Eq. 12",
                    mode.label(),
                    slot >= real_lanes
                );
                // deterministic lanes must also match the σ=0 fast form
                if packed.sigma == 0.0 && packed.noise.iter().all(|&n| n == 0.0) {
                    let det = ddim_update_host(
                        packed.x,
                        out.eps,
                        packed.alpha_in as f64,
                        packed.alpha_out as f64,
                    );
                    let max = out
                        .x_prev
                        .iter()
                        .zip(&det)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(max < 2e-4, "{} deterministic form drift {max}", mode.label());
                }
            }
            for (slot, tr) in trajs.iter_mut().enumerate() {
                tr.advance(batch.lane(slot)).unwrap();
            }
        }
        assert!(trajs.iter().all(|t| t.is_done()));
    }
}
