//! End-to-end tests for the DP τ-optimizer and the `"tau":"opt"` serving
//! path, on fixture artifacts (hermetic reference backend):
//!
//! - the DP emits a valid schedule: strictly increasing boundaries inside
//!   [1, T], exactly S of them, ending at T's neighborhood only if the DP
//!   chose so (validity, not shape, is pinned);
//! - two optimizer runs over freshly-loaded runtimes are byte-identical,
//!   and both match the schedule the fixture generator committed into the
//!   bundle — determinism across process-internal state;
//! - at every budget S ∈ {10, 20, 50} the optimized schedule's fixture
//!   Fréchet is ≤ both closed-form grids under the optimizer's own eval
//!   protocol, and the stored scores are reproducible from scratch;
//! - the cache key moves when the schedule *file content* changes even
//!   though the request's kind tag (`"tau":"opt"`) does not — and stays
//!   put for closed-form kinds;
//! - the router serves `"tau":"opt"` (deterministic, cacheable) and
//!   returns the typed error for an un-optimized (dataset, S) cell.

use ddim_serve::cache::{manifest_digest, CacheKey};
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::coordinator::{ResponseBody, Router};
use ddim_serve::eval::{fid_of_images, load_ref_stats};
use ddim_serve::runtime::{BackendKind, Runtime};
use ddim_serve::sampler::{BatchRunner, SamplerKind};
use ddim_serve::schedule::{
    optimize_tau, optimizer_seed, schedule_path, NoiseMode, OptSchedules, SamplePlan, TauKind,
    EVAL_LANES,
};
use ddim_serve::testing::fixtures;

const BUDGETS: [usize; 3] = [10, 20, 50];

fn fixture_runtime() -> Runtime {
    Runtime::load_with(fixtures::root(), BackendKind::Reference).expect("fixture runtime")
}

fn opt_request(dataset: &str, steps: usize, seed: u64) -> Request {
    Request {
        dataset: dataset.into(),
        steps,
        mode: NoiseMode::Eta(0.0),
        tau: TauKind::Opt,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Generate { count: 2, seed },
        return_images: true,
        cache: CacheMode::Use,
        qos: Default::default(),
    }
}

#[test]
fn optimizer_output_is_a_valid_strictly_increasing_schedule() {
    let mut rt = fixture_runtime();
    let t_max = rt.alphas().t_max();
    for s in BUDGETS {
        let report = optimize_tau(&mut rt, "sprites", s).expect("optimize");
        let tau = &report.schedule.tau;
        assert_eq!(tau.len(), s, "S={s}: budget respected");
        assert!(tau[0] >= 1, "S={s}: boundaries start inside [1, T]");
        assert!(*tau.last().unwrap() <= t_max, "S={s}: boundaries end inside [1, T]");
        assert!(
            tau.windows(2).all(|w| w[0] < w[1]),
            "S={s}: strictly increasing, got {tau:?}"
        );
        assert!(report.candidates >= 2 * s, "S={s}: candidate pool covers both grids");
        assert!(report.evals >= 3, "S={s}: beam winners and both grids were evaluated");
    }
}

#[test]
fn optimizer_is_deterministic_and_matches_the_bundle_schedule() {
    // two runs over independently-loaded runtimes: byte-identical output
    let a = {
        let mut rt = fixture_runtime();
        optimize_tau(&mut rt, "sprites", 10).expect("run a").schedule
    };
    let b = {
        let mut rt = fixture_runtime();
        optimize_tau(&mut rt, "sprites", 10).expect("run b").schedule
    };
    assert_eq!(a.to_json(), b.to_json(), "optimizer must be run-to-run deterministic");

    // and both match what the fixture generator wrote into the bundle
    let on_disk =
        std::fs::read_to_string(schedule_path(&fixtures::root(), "sprites", 10)).expect("bundle schedule");
    assert_eq!(a.to_json(), on_disk, "bundle schedule is the same DP output");
}

#[test]
fn optimized_schedule_beats_both_grids_at_every_budget() {
    let mut rt = fixture_runtime();
    let digest = manifest_digest(rt.manifest());
    let root = rt.manifest().root.clone();
    let registry = OptSchedules::load(&root, digest);
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    for ds in &datasets {
        let reference = load_ref_stats(rt.manifest(), ds).expect("ref stats");
        let mut runner = BatchRunner::new(&rt, ds, EVAL_LANES).expect("runner");
        for s in BUDGETS {
            let sched = registry
                .get(ds, s)
                .unwrap_or_else(|| panic!("bundle has opt schedule for {ds}/S={s}"))
                .clone();
            assert!(
                sched.score <= sched.linear_score && sched.score <= sched.quadratic_score,
                "{ds}/S={s}: stored scores must show opt <= both grids: {sched:?}"
            );
            // recompute the opt score from scratch under the optimizer's
            // eval protocol — the stored number is measured, not asserted
            let plan =
                SamplePlan::generate_with_tau(rt.alphas(), sched.tau.clone(), NoiseMode::Eta(0.0))
                    .expect("plan");
            let images = runner
                .generate(&mut rt, &plan, EVAL_LANES, optimizer_seed(ds, s, 2))
                .expect("generate");
            let fresh = fid_of_images(&images, &reference).expect("fid");
            assert!(
                (fresh - sched.score).abs() < 1e-9,
                "{ds}/S={s}: stored score {} not reproducible (got {fresh})",
                sched.score
            );
        }
    }
}

#[test]
fn cache_key_tracks_schedule_content_not_just_kind_tag() {
    // private tree this test may rewrite
    let dir = std::env::temp_dir().join(format!("ddim-opt-key-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    fixtures::write_into(&dir).unwrap();

    let rt = Runtime::load_with(&dir, BackendKind::Reference).unwrap();
    let digest = manifest_digest(rt.manifest());
    let before = OptSchedules::load(&dir, digest);
    let d1 = before.digest("sprites", 10).expect("schedule present");

    // rewrite the schedule file with a shifted first boundary: same kind
    // tag on the wire, different content on disk
    let path = schedule_path(&dir, "sprites", 10);
    let mut sched = before.get("sprites", 10).unwrap().clone();
    sched.tau[0] -= 1;
    assert!(sched.tau[0] >= 1, "fixture schedules never start at 1");
    std::fs::write(&path, sched.to_json()).unwrap();

    let after = OptSchedules::load(&dir, digest);
    let d2 = after.digest("sprites", 10).expect("rewritten schedule still valid");
    assert_ne!(d1, d2, "content digest must follow the file bytes");
    assert_eq!(after.get("sprites", 10).unwrap().tau, sched.tau);

    let req = opt_request("sprites", 10, 7);
    let k1 = CacheKey::of(&req, digest, BackendKind::Reference, d1);
    let k2 = CacheKey::of(&req, digest, BackendKind::Reference, d2);
    assert_ne!(k1, k2, "same request + kind tag, new schedule content => new key");

    // closed-form kinds ignore the schedule registry entirely
    let mut linear = req;
    linear.tau = TauKind::Linear;
    assert_eq!(
        CacheKey::of(&linear, digest, BackendKind::Reference, d1),
        CacheKey::of(&linear, digest, BackendKind::Reference, d2),
        "non-opt kinds must not key on the opt registry"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn router_serves_opt_tau_and_rejects_unoptimized_cells() {
    let config = ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        max_batch: 8,
        max_lanes: 64,
        queue_capacity: 64,
        shards: 1,
        cache_enabled: true,
        coalesce_enabled: true,
        ..Default::default()
    };
    let router = Router::start(config).unwrap();

    // optimized cell: served, deterministic, cacheable
    let r1 = router.call(opt_request("sprites", 10, 41)).unwrap();
    let ResponseBody::Ok { outputs } = &r1.body else {
        panic!("opt request failed: {:?}", r1.body)
    };
    assert_eq!(outputs.len(), 2);
    assert!(!r1.cached);
    let r2 = router.call(opt_request("sprites", 10, 41)).unwrap();
    assert!(r2.cached, "identical opt request must hit the store");
    let ResponseBody::Ok { outputs: cached } = &r2.body else { panic!("cached opt failed") };
    assert_eq!(outputs, cached, "cached opt bits equal the executed bits");

    // the opt schedule genuinely differs from the linear grid's output
    let mut lin_req = opt_request("sprites", 10, 41);
    lin_req.tau = TauKind::Linear;
    let lin = router.call(lin_req).unwrap();
    let ResponseBody::Ok { outputs: lin_out } = &lin.body else { panic!("linear failed") };
    assert_ne!(outputs, lin_out, "opt and linear schedules produce different samples");

    // un-optimized (dataset, S): typed error naming the remedy
    let missing = router.call(opt_request("sprites", 13, 41)).unwrap();
    let ResponseBody::Error { message } = &missing.body else {
        panic!("S=13 has no optimized schedule and must fail")
    };
    assert!(
        message.contains("no optimized schedule") && message.contains("optimize-tau"),
        "error must name the missing cell and the CLI remedy: {message}"
    );
    router.shutdown();
}
