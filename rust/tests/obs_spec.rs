//! Observability end-to-end: the Prometheus scrape (JSON op and raw
//! HTTP), the structured access log (one line per completed request,
//! spans under `--trace-sample`), rotation keep-K, counter monotonicity
//! across scrapes, and spans-on-the-wire opt-in. Real TCP against the
//! epoll reactors, fixture artifacts on the hermetic reference backend.

use std::io::{Read, Write};
use std::net::TcpStream;

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::Server;
use ddim_serve::jobj;
use ddim_serve::json::{self, Value};
use ddim_serve::obs::prom::validate_exposition;
use ddim_serve::testing::fixtures;

fn cfg() -> ServeConfig {
    ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        ..Default::default()
    }
}

/// Fresh per-test scratch dir (tests run in one process; tag by name).
fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ddim_obs_spec_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn gen(steps: f64, seed: f64, cache: &str) -> Value {
    jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", steps),
        ("eta", 0.0),
        ("count", 1.0),
        ("seed", seed),
        ("cache", cache),
    ]
}

/// First sample value of a family (labeled or not), skipping comments.
fn sample_value(text: &str, name: &str) -> f64 {
    let bare = format!("{name} ");
    let labeled = format!("{name}{{");
    text.lines()
        .find(|l| !l.starts_with('#') && (l.starts_with(&bare) || l.starts_with(&labeled)))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("family {name} missing from exposition"))
}

/// One raw HTTP/1.0 exchange against the JSON-line port; returns
/// (status line, body) — the server closes after flushing, so
/// read-to-EOF delimits the body (no Content-Length in HTTP/1.0).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

/// The scrape is well formed under a stock parser, identical in shape
/// whether served as `{"op":"metrics","format":"prometheus"}` or as
/// `GET /metrics`, carries the build-identity gauge, and every counter
/// is monotone across scrapes with traffic in between.
#[test]
fn prometheus_scrape_is_well_formed_on_both_transports() {
    let server = Server::start(cfg()).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // mixed burst before the first scrape: execution, a cache miss+hit
    for seed in 0..3 {
        let r = c.roundtrip(&gen(4.0, seed as f64, "bypass")).unwrap();
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    }
    c.roundtrip(&gen(6.0, 50.0, "use")).unwrap();
    let hit = c.roundtrip(&gen(6.0, 50.0, "use")).unwrap();
    assert!(hit.get("cached").unwrap().as_bool().unwrap());

    let r = c.roundtrip(&jobj![("op", "metrics"), ("format", "prometheus")]).unwrap();
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    let scrape1 = r.get("prometheus").unwrap().as_str().unwrap().to_string();
    validate_exposition(&scrape1).expect("JSON-op scrape must parse under a stock parser");

    // build identity: constant-1 gauge labeled with the crate version,
    // cache key schema version, and the live manifest digest
    let info = scrape1
        .lines()
        .find(|l| l.starts_with("ddim_build_info{"))
        .expect("ddim_build_info sample");
    assert!(
        info.contains(&format!("version=\"{}\"", env!("CARGO_PKG_VERSION"))),
        "{info}"
    );
    assert!(info.contains("key_version="), "{info}");
    assert!(info.contains("manifest_digest="), "{info}");
    assert!(info.trim_end().ends_with(" 1"), "{info}");
    // the latency histogram ships cumulative buckets with +Inf == count
    assert!(scrape1.contains("ddim_request_latency_seconds_bucket{le=\"+Inf\"}"));
    assert!(scrape1.contains("ddim_request_latency_seconds_count"));
    // per-shard and cache families carry their labels
    assert!(scrape1.contains("ddim_shard_requests_completed_total{"));
    assert!(scrape1.contains("ddim_cache_hits_total"));

    // more traffic, then the second scrape over raw HTTP on the same port
    for seed in 10..13 {
        c.roundtrip(&gen(4.0, seed as f64, "bypass")).unwrap();
    }
    let (status, scrape2) = http_get(server.addr(), "/metrics");
    assert_eq!(status, "HTTP/1.0 200 OK");
    validate_exposition(&scrape2).expect("HTTP scrape must parse under a stock parser");

    // counter semantics: every counter family is monotone non-decreasing
    for name in [
        "ddim_requests_completed_total",
        "ddim_steps_executed_total",
        "ddim_executable_calls_total",
        "ddim_cache_hits_total",
        "ddim_cache_misses_total",
        "ddim_connections_total",
        "ddim_wakeups_total",
        "ddim_access_log_lines_total",
    ] {
        let (a, b) = (sample_value(&scrape1, name), sample_value(&scrape2, name));
        assert!(b >= a, "counter {name} decreased across scrapes: {a} -> {b}");
    }
    assert!(
        sample_value(&scrape2, "ddim_requests_completed_total")
            > sample_value(&scrape1, "ddim_requests_completed_total"),
        "traffic between scrapes must move the completion counter"
    );
    assert!(
        sample_value(&scrape2, "ddim_uptime_seconds")
            >= sample_value(&scrape1, "ddim_uptime_seconds")
    );

    // unknown paths 404 without wedging the port for JSON traffic
    let (status, _) = http_get(server.addr(), "/nope");
    assert_eq!(status, "HTTP/1.0 404 Not Found");
    let pong = c.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());
    server.shutdown();
}

/// The JSON `{"op":"metrics"}` body carries the same build identity
/// (uptime, crate version, key schema version, manifest digest) plus
/// the observability plane's own health.
#[test]
fn json_metrics_carry_build_identity_and_obs_health() {
    let server = Server::start(cfg()).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let m = c.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert!(m.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(m.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
    assert_eq!(
        m.get("key_version").unwrap().as_u64().unwrap(),
        ddim_serve::cache::KEY_VERSION as u64
    );
    let digest = m.get("manifest_digest").unwrap().as_str().unwrap();
    assert_eq!(digest.len(), 16, "zero-padded hex digest: {digest}");
    assert!(digest.chars().all(|c| c.is_ascii_hexdigit()));
    let o = m.get("obs").unwrap();
    assert!(!o.get("access_log_enabled").unwrap().as_bool().unwrap());
    assert_eq!(o.get("trace_sample").unwrap().as_u64().unwrap(), 0);
    assert_eq!(o.get("access_log_dropped").unwrap().as_u64().unwrap(), 0);
    server.shutdown();
}

/// One access-log line per completed request — ok, cache hit, and
/// error outcomes — with spans on every executed request when
/// `--trace-sample 1`, and correct cache dispositions throughout.
#[test]
fn access_log_writes_one_line_per_completed_request() {
    let dir = tmp_dir("burst");
    let path = dir.join("access.log");
    let mut config = cfg();
    config.access_log = path.to_str().unwrap().to_string();
    config.trace_sample = 1;
    let server = Server::start(config).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // 5 request ops -> 5 lines; ping/metrics are not requests
    let r = c.roundtrip(&gen(3.0, 1.0, "bypass")).unwrap();
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    // sampled traces never leak onto the wire
    assert!(r.get_opt("spans").is_none(), "sampled trace leaked: {r:?}");
    c.roundtrip(&gen(4.0, 2.0, "bypass")).unwrap();
    c.roundtrip(&gen(6.0, 3.0, "use")).unwrap();
    let hit = c.roundtrip(&gen(6.0, 3.0, "use")).unwrap();
    assert!(hit.get("cached").unwrap().as_bool().unwrap());
    let err = c
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "no_such_dataset"),
            ("steps", 5.0),
            ("eta", 0.0),
            ("count", 1.0),
            ("seed", 4.0),
        ])
        .unwrap();
    assert!(!err.get("ok").unwrap().as_bool().unwrap());
    c.roundtrip(&jobj![("op", "ping")]).unwrap();
    c.roundtrip(&jobj![("op", "metrics")]).unwrap();

    // shutdown drains the writer thread; the file is complete after it
    server.shutdown();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Value> =
        text.lines().map(|l| json::parse(l).expect("log line parses")).collect();
    assert_eq!(lines.len(), 5, "one line per request op:\n{text}");

    let by_steps = |s: usize| -> Vec<&Value> {
        lines
            .iter()
            .filter(|v| v.get("steps_requested").unwrap().as_usize().unwrap() == s)
            .collect()
    };
    for v in &lines {
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "generate");
        assert!(v.get("bytes_out").unwrap().as_usize().unwrap() > 0);
        assert!(v.get("total_s").unwrap().as_f64().unwrap() >= 0.0);
        assert!(v.get("sampler").is_ok() && v.get("tau").is_ok() && v.get("priority").is_ok());
    }
    for s in [3usize, 4] {
        let v = by_steps(s)[0];
        assert_eq!(v.get("outcome").unwrap().as_str().unwrap(), "ok");
        assert_eq!(v.get("cache").unwrap().as_str().unwrap(), "bypass");
        assert_eq!(v.get("steps_executed").unwrap().as_usize().unwrap(), s);
        // trace_sample=1: every executed request carries stage spans
        let sp = v.get("spans").unwrap_or_else(|_| panic!("S={s} line missing spans"));
        for stage in ["queue_s", "pack_s", "device_s", "advance_s", "publish_s", "total_s"] {
            assert!(sp.get(stage).unwrap().as_f64().unwrap() >= 0.0);
        }
        assert!(sp.get("total_s").unwrap().as_f64().unwrap() > 0.0);
    }
    let pair = by_steps(6);
    assert_eq!(pair.len(), 2);
    let dispositions: Vec<&str> =
        pair.iter().map(|v| v.get("cache").unwrap().as_str().unwrap()).collect();
    assert!(dispositions.contains(&"miss") && dispositions.contains(&"hit"), "{dispositions:?}");
    // a hit never touched an engine, so there are no stage spans to log
    let hit_line = pair
        .iter()
        .find(|v| v.get("cache").unwrap().as_str().unwrap() == "hit")
        .unwrap();
    assert!(hit_line.get_opt("spans").is_none());
    let err_line = by_steps(5)[0];
    assert_eq!(err_line.get("outcome").unwrap().as_str().unwrap(), "error");
    assert_eq!(err_line.get("dataset").unwrap().as_str().unwrap(), "no_such_dataset");
    assert!(err_line.get_opt("reject_reason").is_none());
}

/// Size-triggered rotation retains exactly `keep` shifted generations
/// (PATH.1 .. PATH.keep) and every retained line is intact JSON.
#[test]
fn rotation_retains_exactly_keep_generations() {
    let dir = tmp_dir("rotate");
    let path = dir.join("access.log");
    let mut config = cfg();
    config.access_log = path.to_str().unwrap().to_string();
    config.log_rotate_bytes = 256; // a couple of lines per generation
    config.log_keep = 2;
    let server = Server::start(config).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    for seed in 0..30 {
        let r = c.roundtrip(&gen(2.0, seed as f64, "bypass")).unwrap();
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    }
    server.shutdown();

    assert!(path.exists(), "live file present");
    assert!(path.with_extension("log.1").exists(), "first rotated generation");
    assert!(path.with_extension("log.2").exists(), "second rotated generation");
    assert!(!path.with_extension("log.3").exists(), "keep=2 prunes older generations");
    let mut total = 0usize;
    for p in [path.clone(), path.with_extension("log.1"), path.with_extension("log.2")] {
        for line in std::fs::read_to_string(&p).unwrap().lines() {
            json::parse(line).unwrap_or_else(|e| panic!("{p:?} corrupt line: {e}"));
            total += 1;
        }
    }
    assert!(total >= 2, "retained generations hold the newest lines");
    assert!(total < 30, "old generations beyond keep were pruned");
}

/// Spans ride the wire only for requests that ask with `"trace":true`;
/// the response then carries every stage on the engine-shared clock.
#[test]
fn spans_on_the_wire_are_explicit_opt_in() {
    let mut config = cfg();
    config.trace_sample = 1; // sampling alone must not leak to the wire
    let server = Server::start(config).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    let plain = c.roundtrip(&gen(4.0, 70.0, "bypass")).unwrap();
    assert!(plain.get("ok").unwrap().as_bool().unwrap());
    assert!(plain.get_opt("spans").is_none(), "{plain:?}");

    let mut traced_req = gen(4.0, 71.0, "bypass");
    traced_req.set("trace", Value::Bool(true)).unwrap();
    let traced = c.roundtrip(&traced_req).unwrap();
    assert!(traced.get("ok").unwrap().as_bool().unwrap(), "{traced:?}");
    let sp = traced.get("spans").expect("explicit trace returns spans");
    for stage in ["queue_s", "pack_s", "device_s", "advance_s", "publish_s", "total_s"] {
        assert!(sp.get(stage).unwrap().as_f64().unwrap() >= 0.0, "{stage}");
    }
    let total = sp.get("total_s").unwrap().as_f64().unwrap();
    let latency = traced.get("latency_s").unwrap().as_f64().unwrap();
    assert!(total >= latency, "transport total includes the engine latency");
    assert!(sp.get("device_s").unwrap().as_f64().unwrap() > 0.0, "execution was timed");

    // an explicit trace on a cache hit has no execution to time: the
    // response stays span-free rather than inventing zeros
    c.roundtrip(&gen(6.0, 72.0, "use")).unwrap();
    let mut hit_req = gen(6.0, 72.0, "use");
    hit_req.set("trace", Value::Bool(true)).unwrap();
    let hit = c.roundtrip(&hit_req).unwrap();
    assert!(hit.get("cached").unwrap().as_bool().unwrap());
    assert!(hit.get_opt("spans").is_none(), "{hit:?}");
    server.shutdown();
}
