//! Cross-language golden tests: the rust-loaded HLO executable must
//! reproduce the outputs the python (jax + Pallas) build computed for fixed
//! inputs, and the rust feature extractor must match the python one.
//!
//! These are the tests that pin the whole L1→L2→L3 stack together. They
//! need `make artifacts` to have run; they skip (with a loud message) when
//! the artifact tree is absent so `cargo test` works on a fresh checkout.

use ddim_serve::artifacts::{read_tensor, read_tensor_f64};
use ddim_serve::runtime::{Runtime, StepOutput};
use ddim_serve::stats::{extract_features, FEAT_DIM};

const ROOT: &str = env!("CARGO_MANIFEST_DIR");

fn artifacts_root() -> String {
    format!("{ROOT}/artifacts")
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_root()).join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn golden_denoise_step_matches_python() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_root()).unwrap();
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    for ds in datasets {
        for bucket in [1usize, 4] {
            let g = |name: &str| {
                read_tensor(rt.manifest().golden_path(&ds, &format!("b{bucket}_{name}")))
                    .unwrap_or_else(|e| panic!("golden {ds}/b{bucket}_{name}: {e}"))
            };
            let x = g("x");
            let t = g("t");
            let a_t = g("alpha_t");
            let a_p = g("alpha_prev");
            let sigma = g("sigma");
            let noise = g("noise");
            let want_x_prev = g("x_prev");
            let want_eps = g("eps");
            let want_x0 = g("x0");

            let dim = rt.manifest().sample_dim();
            let mut out = StepOutput::zeros(bucket * dim);
            let exe = rt.executable(&ds, bucket).unwrap();
            exe.run(
                x.data(),
                t.data(),
                a_t.data(),
                a_p.data(),
                sigma.data(),
                noise.data(),
                &mut out,
            )
            .unwrap();

            let check = |name: &str, got: &[f32], want: &[f32]| {
                let max = got
                    .iter()
                    .zip(want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    max < 2e-4,
                    "{ds} b{bucket} {name}: max abs diff {max} exceeds tolerance"
                );
            };
            check("x_prev", &out.x_prev, want_x_prev.data());
            check("eps", &out.eps, want_eps.data());
            check("x0", &out.x0, want_x0.data());
        }
    }
}

#[test]
fn golden_features_match_python() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_root()).unwrap();
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    for ds in datasets {
        let imgs = read_tensor(rt.manifest().golden_path(&ds, "feat_imgs")).unwrap();
        let (shape, want) =
            read_tensor_f64(rt.manifest().golden_path(&ds, "feat_out")).unwrap();
        assert_eq!(shape[1], FEAT_DIM);
        let n = shape[0];
        let dim = rt.manifest().sample_dim();
        for i in 0..n {
            let img = &imgs.data()[i * dim..(i + 1) * dim];
            let got = extract_features(img);
            for d in 0..FEAT_DIM {
                let w = want[i * FEAT_DIM + d];
                // imgs pass through f32, python features computed in f64 on
                // the same values: agreement should be ~1e-7
                assert!(
                    (got[d] - w).abs() < 1e-6,
                    "{ds} img {i} feature {d}: rust {} vs python {w}",
                    got[d]
                );
            }
        }
    }
}

#[test]
fn ref_stats_load_and_are_sane() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_root()).unwrap();
    for ds in rt.manifest().datasets.keys() {
        let fit = ddim_serve::eval::load_ref_stats(rt.manifest(), ds).unwrap();
        let cov = fit.covariance().unwrap();
        assert!(cov.is_symmetric(1e-9), "{ds} ref cov not symmetric");
        // the reference distribution should score ~0 against itself
        let d = ddim_serve::stats::frechet_distance(&fit, &fit).unwrap();
        assert!(d < 1e-9, "{ds}: self-FID {d}");
    }
}
