//! Golden tests over the artifact interchange formats.
//!
//! Two tiers:
//!
//! - **Hermetic (default)**: `testing::fixtures` writes a full synthetic
//!   bundle — step goldens composed independently through the host Eq.-12
//!   arithmetic, feature goldens, reference stats — and the tests pin the
//!   executable path (`Runtime::load` → cache → submit/wait), the
//!   tensorfile round trip, and the eval pipeline against them. Zero
//!   skips, no python, no XLA.
//! - **Real artifacts (`#[ignore]`)**: the original cross-language pins
//!   against python-dumped goldens in `artifacts/`. Run with
//!   `cargo test -- --ignored` after `make artifacts` (with `--features
//!   xla` for the compiled backend).

use ddim_serve::artifacts::{read_tensor, read_tensor_f64};
use ddim_serve::runtime::{Runtime, StepOutput};
use ddim_serve::stats::{extract_features, FEAT_DIM};
use ddim_serve::testing::fixtures;

const ROOT: &str = env!("CARGO_MANIFEST_DIR");

fn real_artifacts_root() -> String {
    format!("{ROOT}/artifacts")
}

macro_rules! require_real_artifacts {
    () => {
        if !std::path::Path::new(&real_artifacts_root()).join("manifest.json").exists() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
            return;
        }
    };
}

/// Drive the executable over every dataset's fixed golden inputs and
/// compare all three outputs against the bundled expectations.
fn check_step_goldens(mut rt: Runtime, tolerance: f32) {
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    for ds in datasets {
        for bucket in [1usize, 4] {
            let g = |name: &str| {
                read_tensor(rt.manifest().golden_path(&ds, &format!("b{bucket}_{name}")))
                    .unwrap_or_else(|e| panic!("golden {ds}/b{bucket}_{name}: {e}"))
            };
            let x = g("x");
            let t = g("t");
            let a_t = g("alpha_t");
            let a_p = g("alpha_prev");
            let sigma = g("sigma");
            let noise = g("noise");
            let want_x_prev = g("x_prev");
            let want_eps = g("eps");
            let want_x0 = g("x0");

            let dim = rt.manifest().sample_dim();
            let mut out = StepOutput::zeros(bucket * dim);
            let exe = rt.executable(&ds, bucket).unwrap();
            exe.run(
                x.data(),
                t.data(),
                a_t.data(),
                a_p.data(),
                sigma.data(),
                noise.data(),
                &mut out,
            )
            .unwrap();

            let check = |name: &str, got: &[f32], want: &[f32]| {
                let max = got
                    .iter()
                    .zip(want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    max < tolerance,
                    "{ds} b{bucket} {name}: max abs diff {max} exceeds tolerance"
                );
            };
            check("x_prev", &out.x_prev, want_x_prev.data());
            check("eps", &out.eps, want_eps.data());
            check("x0", &out.x0, want_x0.data());
        }
    }
}

/// Features extracted in-process must match the bundled `feat_out` f64
/// tensors for the bundled `feat_imgs` inputs.
fn check_feature_goldens(rt: &Runtime, tolerance: f64) {
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    for ds in datasets {
        let imgs = read_tensor(rt.manifest().golden_path(&ds, "feat_imgs")).unwrap();
        let (shape, want) = read_tensor_f64(rt.manifest().golden_path(&ds, "feat_out")).unwrap();
        assert_eq!(shape[1], FEAT_DIM);
        let n = shape[0];
        let dim = rt.manifest().sample_dim();
        for i in 0..n {
            let img = &imgs.data()[i * dim..(i + 1) * dim];
            let got = extract_features(img);
            for d in 0..FEAT_DIM {
                let w = want[i * FEAT_DIM + d];
                assert!(
                    (got[d] - w).abs() < tolerance,
                    "{ds} img {i} feature {d}: rust {} vs golden {w}",
                    got[d]
                );
            }
        }
    }
}

fn check_ref_stats(rt: &Runtime) {
    for ds in rt.manifest().datasets.keys() {
        let fit = ddim_serve::eval::load_ref_stats(rt.manifest(), ds).unwrap();
        let cov = fit.covariance().unwrap();
        assert!(cov.is_symmetric(1e-9), "{ds} ref cov not symmetric");
        // the reference distribution should score ~0 against itself
        let d = ddim_serve::stats::frechet_distance(&fit, &fit).unwrap();
        assert!(d < 1e-9, "{ds}: self-FID {d}");
    }
}

// --- hermetic tier (fixtures, reference backend, zero skips) ---------------

#[test]
fn golden_denoise_step_matches_fixture_expectations() {
    // fixture expectations are composed through ddim_update_host_sigma on
    // f32-rounded inputs — independent of the executable code path, so
    // this pins Runtime::load → bucket cache → submit/wait end to end
    let rt = Runtime::load(fixtures::root()).unwrap();
    check_step_goldens(rt, 2e-4);
}

#[test]
fn golden_features_match_fixture_tensorfiles() {
    // pins the f32-image / f64-feature tensorfile interchange: a change to
    // either the extractor or the on-disk format shows up as drift here
    let rt = Runtime::load(fixtures::root()).unwrap();
    check_feature_goldens(&rt, 1e-12);
}

#[test]
fn ref_stats_load_and_are_sane() {
    let rt = Runtime::load(fixtures::root()).unwrap();
    check_ref_stats(&rt);
}

// --- real-artifact tier (#[ignore]; needs `make artifacts`) ----------------

#[test]
#[ignore = "needs real artifacts (make artifacts) + --features xla; cross-language python pin"]
fn golden_denoise_step_matches_python() {
    require_real_artifacts!();
    // the python goldens were computed by the trained model, so only the
    // compiled backend can reproduce them — the reference backend's
    // synthetic ε is deliberately unrelated
    #[cfg(feature = "xla")]
    {
        let rt = Runtime::load_with(
            real_artifacts_root(),
            ddim_serve::runtime::BackendKind::Xla,
        )
        .unwrap();
        check_step_goldens(rt, 2e-4);
    }
    #[cfg(not(feature = "xla"))]
    eprintln!("SKIP: golden_denoise_step_matches_python needs --features xla (real PJRT wrapper)");
}

#[test]
#[ignore = "needs real artifacts (make artifacts); cross-language python pin"]
fn golden_features_match_python() {
    require_real_artifacts!();
    let rt = Runtime::load(real_artifacts_root()).unwrap();
    // imgs pass through f32, python features computed in f64 on the same
    // values: agreement should be ~1e-7
    check_feature_goldens(&rt, 1e-6);
}

#[test]
#[ignore = "needs real artifacts (make artifacts)"]
fn real_ref_stats_load_and_are_sane() {
    require_real_artifacts!();
    let rt = Runtime::load(real_artifacts_root()).unwrap();
    check_ref_stats(&rt);
}
