//! Shared helpers for the paper-table benches (`cargo bench`). Each bench
//! is a `harness = false` binary that regenerates one table or figure of
//! the paper and prints it in the paper's layout.
//!
//! Sample counts scale with `DDIM_BENCH_N` (default 128 per Table-1 cell);
//! `DDIM_BENCH_QUICK=1` runs a smoke-sized sweep for CI.

#![allow(dead_code)]

use ddim_serve::eval::{fid_of_images, load_ref_stats};
use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::stats::GaussianFit;

pub fn artifacts_root() -> String {
    std::env::var("DDIM_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

pub fn require_artifacts() -> Option<Runtime> {
    let root = artifacts_root();
    if !std::path::Path::new(&root).join("manifest.json").exists() {
        println!("SKIP: artifacts missing at {root} — run `make artifacts`");
        return None;
    }
    Some(Runtime::load(root).expect("artifact load"))
}

pub fn quick() -> bool {
    std::env::var("DDIM_BENCH_QUICK").as_deref() == Ok("1")
}

/// Samples per FID cell.
pub fn cell_n(default_n: usize) -> usize {
    if quick() {
        return 16;
    }
    std::env::var("DDIM_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_n)
}

pub fn s_list() -> Vec<usize> {
    if quick() {
        vec![5, 10]
    } else {
        vec![5, 10, 20, 50, 100]
    }
}

/// One Table-1/3 cell: generate `n` samples under (S, mode) and score
/// proxy-FID against the dataset's reference stats.
pub fn fid_cell(
    rt: &mut Runtime,
    runner: &mut BatchRunner,
    reference: &GaussianFit,
    tau: TauKind,
    s: usize,
    mode: NoiseMode,
    n: usize,
    seed: u64,
) -> f64 {
    let plan = SamplePlan::generate(rt.alphas(), tau, s, mode).expect("plan");
    let images = runner.generate(rt, &plan, n, seed).expect("generate");
    fid_of_images(&images, reference).expect("fid")
}

/// Like [`fid_cell`] but over an explicit τ subsequence (e.g. a
/// DP-optimized schedule) instead of a closed-form kind.
pub fn fid_cell_tau(
    rt: &mut Runtime,
    runner: &mut BatchRunner,
    reference: &GaussianFit,
    tau: Vec<usize>,
    mode: NoiseMode,
    n: usize,
    seed: u64,
) -> f64 {
    let plan = SamplePlan::generate_with_tau(rt.alphas(), tau, mode).expect("plan");
    let images = runner.generate(rt, &plan, n, seed).expect("generate");
    fid_of_images(&images, reference).expect("fid")
}

pub fn reference_for(rt: &Runtime, dataset: &str) -> GaussianFit {
    load_ref_stats(rt.manifest(), dataset).expect("ref stats")
}

/// Print a row of f64 cells with a label, paper-table style.
pub fn print_row(label: &str, cells: &[f64]) {
    print!("{label:>10} |");
    for c in cells {
        print!(" {c:>8.2}");
    }
    println!();
}

pub fn print_header(first: &str, s_values: &[usize]) {
    print!("{first:>10} |");
    for s in s_values {
        print!(" {s:>8}");
    }
    println!();
    println!("{}", "-".repeat(12 + 9 * s_values.len()));
}
