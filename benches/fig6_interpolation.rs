//! Fig. 6 reproduction, quantitative: slerp in x_T decoded at dim(τ)=50.
//! For each latent pair we decode 11 interpolants and measure path
//! smoothness (max adjacent feature jump / endpoint distance). A
//! semantically meaningful interpolation moves gradually (ratio near
//! 1/10); a DDPM control with the same latents jumps around (ratio ≳ 1
//! because intermediate samples are re-randomised).
//!
//!     cargo bench --bench fig6_interpolation

#[path = "common.rs"]
mod common;

use ddim_serve::eval::path_smoothness;
use ddim_serve::rng::{slerp, GaussianSource};
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

const ALPHAS: usize = 11;

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let pairs = if common::quick() { 2 } else { 8 };
    let steps = 50usize;
    let dim = rt.manifest().sample_dim();
    let img = rt.manifest().img;

    println!("=== Fig. 6: slerp interpolation smoothness, dim(tau)={steps}, {pairs} pairs ===");
    for ds in ["blobs", "sprites"] {
        let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
        let mut g = GaussianSource::seeded(0xF6);
        let mut latents = Vec::new();
        for _ in 0..pairs {
            let a = g.vec(dim);
            let b = g.vec(dim);
            for k in 0..ALPHAS {
                latents.push(slerp(&a, &b, k as f64 / (ALPHAS - 1) as f64));
            }
        }
        println!("\n--- {ds} ---");
        println!("{:>6} | {:>16} | {:>16}", "pair", "DDIM max-jump", "DDPM max-jump");
        let mut stats = Vec::new();
        for (label, mode) in [("ddim", NoiseMode::Eta(0.0)), ("ddpm", NoiseMode::Eta(1.0))] {
            let plan =
                SamplePlan::generate(rt.alphas(), TauKind::Linear, steps, mode).expect("plan");
            let images = runner.run_from(&mut rt, &plan, latents.clone(), 0x60).expect("run");
            let per_pair: Vec<(f64, f64)> = (0..pairs)
                .map(|p| path_smoothness(&images[p * ALPHAS..(p + 1) * ALPHAS]))
                .collect();
            stats.push(per_pair);
            // save the first grid of each mode
            let refs: Vec<&[f32]> = images[..ALPHAS * pairs.min(4)]
                .iter()
                .map(|v| v.as_slice())
                .collect();
            let grid = tile_grid(&refs, pairs.min(4), ALPHAS, img, img).expect("grid");
            save_pgm(format!("out/fig6/{ds}_{label}.pgm"), &grid).expect("save");
        }
        let mut ddim_mean = 0.0;
        let mut ddpm_mean = 0.0;
        for p in 0..pairs {
            println!(
                "{p:>6} | {:>16.3} | {:>16.3}",
                stats[0][p].0, stats[1][p].0
            );
            ddim_mean += stats[0][p].0 / pairs as f64;
            ddpm_mean += stats[1][p].0 / pairs as f64;
        }
        println!(
            "[{}] {ds}: DDIM paths smoother than DDPM on average ({ddim_mean:.3} vs {ddpm_mean:.3}; even = {:.3})",
            if ddim_mean < ddpm_mean { "PASS" } else { "WARN" },
            1.0 / (ALPHAS - 1) as f64
        );
        println!("grids -> out/fig6/{ds}_{{ddim,ddpm}}.pgm");
    }
}
