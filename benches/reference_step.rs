//! Reference-backend step-kernel bench: scalar baseline vs the
//! structure-of-arrays kernel (fixed-width unrolling), vs the threaded
//! worker-pool path, vs the f16-stored / f32-accumulated weight path.
//!
//! Needs no artifacts: the synthetic ε-model is built straight from a
//! `DatasetInfo`, so this runs anywhere tier-1 runs. Besides the table it
//! dumps `BENCH_reference.json` and — with `DDIM_BENCH_GATE=1` — compares
//! the measured *speedup ratio* (optimized vs scalar, both measured in
//! this same run, so the gate is hardware-portable) against the committed
//! baseline and exits nonzero on a >30% regression.
//!
//! Correctness is asserted inline before anything is timed: the unrolled
//! and threaded paths must be bitwise-identical to the scalar baseline,
//! the f16 path tolerance-bounded, and the warm loop allocation-free.
//!
//!     cargo bench --bench reference_step
//!     DDIM_BENCH_GATE=1 cargo bench --bench reference_step   # CI gate

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use ddim_serve::artifacts::DatasetInfo;
use ddim_serve::jobj;
use ddim_serve::json::{self, Value};
use ddim_serve::rng::Pcg64;
use ddim_serve::runtime::reference::{compute_scalar_into, UNROLL};
use ddim_serve::runtime::{RefModel, RefPrecision, StepExecutable, StepOutput, WorkerPool};

const RESULT_PATH: &str = "BENCH_reference.json";
/// Gate threshold: fail if this run's speedup ratio drops below 70% of the
/// committed baseline's (>30% regression).
const GATE_MIN_RATIO: f64 = 0.7;
const GATE_WARN_RATIO: f64 = 1.3;

/// One packed problem instance: deterministic pseudo-random states and a
/// heterogeneous schedule (η > 0 lanes included) at (bucket × dim).
struct Problem {
    bucket: usize,
    dim: usize,
    x: Vec<f32>,
    t: Vec<f32>,
    a_t: Vec<f32>,
    a_p: Vec<f32>,
    sigma: Vec<f32>,
    noise: Vec<f32>,
}

impl Problem {
    fn new(bucket: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let n = bucket * dim;
        Self {
            bucket,
            dim,
            x: (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect(),
            noise: (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            t: (0..bucket).map(|s| 37.0 + 11.0 * s as f32).collect(),
            a_t: (0..bucket).map(|s| 0.92 - 0.05 * s as f32).collect(),
            a_p: (0..bucket).map(|s| 0.96 - 0.04 * s as f32).collect(),
            // every third lane stochastic, like a mixed serving tick
            sigma: (0..bucket).map(|s| if s % 3 == 0 { 0.12 } else { 0.0 }).collect(),
        }
    }
}

fn model_for(dim: usize) -> Arc<RefModel> {
    let info = DatasetInfo { hlo: vec![], params: 123_456, final_loss: 0.0421, ref_n: 64 };
    Arc::new(RefModel::from_manifest("sprites", &info, dim, 1000))
}

/// ms per scalar-baseline call.
fn time_scalar(m: &RefModel, p: &Problem, iters: usize) -> (f64, StepOutput) {
    let mut out = StepOutput::zeros(p.bucket * p.dim);
    let run = |out: &mut StepOutput| {
        compute_scalar_into(
            m, p.bucket, p.dim, &p.x, &p.t, &p.a_t, &p.a_p, &p.sigma, &p.noise, out,
        )
    };
    run(&mut out); // warm
    let t0 = Instant::now();
    for _ in 0..iters {
        run(&mut out);
    }
    (t0.elapsed().as_secs_f64() * 1e3 / iters as f64, out)
}

/// ms per optimized-kernel call through the real `StepExecutable` path,
/// asserting the warm loop allocates nothing.
fn time_exec(exe: &StepExecutable, p: &Problem, iters: usize) -> (f64, StepOutput) {
    let mut out = StepOutput::zeros(p.bucket * p.dim);
    let run = |out: &mut StepOutput| {
        exe.run(&p.x, &p.t, &p.a_t, &p.a_p, &p.sigma, &p.noise, out).expect("step")
    };
    run(&mut out); // warm
    exe.take_ref_stats(); // discard cold-start growth
    let t0 = Instant::now();
    for _ in 0..iters {
        run(&mut out);
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let (_, bytes) = exe.take_ref_stats();
    assert_eq!(bytes, 0, "warm bench loop must be allocation-free");
    (ms, out)
}

fn exec_with(m: &Arc<RefModel>, p: &Problem, threads: usize, prec: RefPrecision) -> StepExecutable {
    StepExecutable::reference_with(
        Arc::clone(m),
        p.bucket,
        p.dim,
        Arc::new(WorkerPool::new(threads)),
        prec,
    )
    .expect("exe")
}

#[allow(clippy::type_complexity)]
fn bench_cell(p: &Problem, threads: usize, iters: usize) -> (f64, f64, f64, f64, f64) {
    let m = model_for(p.dim);
    let (scalar_ms, scalar_out) = time_scalar(&m, p, iters);
    let unrolled = exec_with(&m, p, 1, RefPrecision::F32);
    let (unrolled_ms, unrolled_out) = time_exec(&unrolled, p, iters);
    let threaded = exec_with(&m, p, threads, RefPrecision::F32);
    let (threaded_ms, threaded_out) = time_exec(&threaded, p, iters);
    let half = exec_with(&m, p, threads, RefPrecision::F16);
    let (f16_ms, f16_out) = time_exec(&half, p, iters);

    // correctness before speed: the non-negotiable invariant of the PR
    assert_eq!(unrolled_out.x_prev, scalar_out.x_prev, "unrolled != scalar (x_prev)");
    assert_eq!(unrolled_out.eps, scalar_out.eps, "unrolled != scalar (eps)");
    assert_eq!(unrolled_out.x0, scalar_out.x0, "unrolled != scalar (x0)");
    assert_eq!(threaded_out.x_prev, scalar_out.x_prev, "threaded != scalar (x_prev)");
    assert_eq!(threaded_out.eps, scalar_out.eps, "threaded != scalar (eps)");
    assert_eq!(threaded_out.x0, scalar_out.x0, "threaded != scalar (x0)");
    let drift = f16_out
        .x_prev
        .iter()
        .zip(&scalar_out.x_prev)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(drift < 5e-2, "f16 drift {drift} out of tolerance");

    (scalar_ms, unrolled_ms, threaded_ms, f16_ms, drift as f64)
}

fn steps_per_s(bucket: usize, ms: f64) -> f64 {
    bucket as f64 * 1e3 / ms
}

fn main() {
    let threads = ddim_serve::runtime::RefOptions::default().resolved_threads();
    let iters = if common::quick() { 20 } else { 200 };
    let gate = std::env::var("DDIM_BENCH_GATE").as_deref() == Ok("1");

    // the committed baseline must be read before this run overwrites it
    let baseline_speedup: Option<f64> = std::fs::read_to_string(RESULT_PATH)
        .ok()
        .and_then(|s| json::parse(&s).ok())
        .and_then(|v| {
            v.get("main").ok().and_then(|m| m.get("speedup_total").ok()?.as_f64().ok())
        });

    println!("=== reference_step: scalar vs SoA-unrolled vs threaded vs f16 ===");
    println!("unroll width {UNROLL}, worker pool {threads} threads, {iters} iters/cell\n");
    println!(
        "{:>6} | {:>6} | {:>10} | {:>10} | {:>10} | {:>10} | {:>7} | {:>7} | {:>7}",
        "bucket", "dim", "scalar ms", "unroll ms", "thread ms", "f16 ms", "x unr", "x thr", "x f16"
    );

    // the acceptance cell first (bucket 16 × dim 3072), then a small sweep
    // over odd shapes so layout regressions off the happy path show up
    let cells = [(16usize, 3072usize), (4, 3072), (16, 257), (3, 63)];
    let mut sweep: Vec<Value> = Vec::new();
    let mut main_cell: Option<Value> = None;
    let mut main_speedup = 0.0f64;
    for (i, &(bucket, dim)) in cells.iter().enumerate() {
        let p = Problem::new(bucket, dim, 7 + i as u64);
        let (scalar_ms, unrolled_ms, threaded_ms, f16_ms, f16_drift) =
            bench_cell(&p, threads, iters);
        let (su, st, sf) =
            (scalar_ms / unrolled_ms, scalar_ms / threaded_ms, scalar_ms / f16_ms);
        println!(
            "{bucket:>6} | {dim:>6} | {scalar_ms:>10.3} | {unrolled_ms:>10.3} | {threaded_ms:>10.3} | {f16_ms:>10.3} | {su:>6.2}x | {st:>6.2}x | {sf:>6.2}x"
        );
        let row = jobj![
            ("bucket", bucket),
            ("dim", dim),
            ("scalar_ms", scalar_ms),
            ("unrolled_ms", unrolled_ms),
            ("threaded_ms", threaded_ms),
            ("f16_ms", f16_ms),
            ("scalar_steps_per_s", steps_per_s(bucket, scalar_ms)),
            ("threaded_steps_per_s", steps_per_s(bucket, threaded_ms)),
            ("speedup_unroll", su),
            ("speedup_threads", st / su.max(1e-12)),
            ("speedup_total", st),
            ("speedup_f16", sf),
            ("f16_max_drift", f16_drift),
        ];
        if i == 0 {
            main_speedup = st;
            main_cell = Some(row.clone());
        }
        sweep.push(row);
    }

    let dump = jobj![
        ("bench", "reference_step"),
        ("quick", common::quick()),
        ("threads", threads),
        ("unroll", UNROLL),
        ("iters", iters),
        ("main", main_cell.expect("main cell ran")),
        ("sweep", Value::Arr(sweep)),
    ];

    println!(
        "\nmain cell (16 x 3072): {main_speedup:.2}x total speedup over the scalar baseline \
         ({} the 4x acceptance bar on a 4-core runner)",
        if main_speedup >= 4.0 { "meets" } else { "below" }
    );

    let mut fail = false;
    match (gate, baseline_speedup) {
        (true, Some(base)) => {
            let ratio = main_speedup / base;
            println!(
                "gate: measured speedup {main_speedup:.2}x vs committed baseline {base:.2}x \
                 (ratio {ratio:.2}, floor {GATE_MIN_RATIO})"
            );
            if ratio < GATE_MIN_RATIO {
                eprintln!(
                    "GATE FAIL: reference-kernel speedup regressed >30% vs the committed \
                     {RESULT_PATH}. If intentional, re-run the bench on a quiet machine and \
                     commit the regenerated {RESULT_PATH}."
                );
                fail = true;
            } else if ratio > GATE_WARN_RATIO {
                println!(
                    "gate: improvement >30% over the committed baseline — consider \
                     committing the regenerated {RESULT_PATH} so the gate tracks it"
                );
            }
        }
        (true, None) => println!(
            "gate: no committed {RESULT_PATH} baseline found — recording this run as the \
             new baseline, nothing to compare against"
        ),
        (false, _) => {}
    }

    match std::fs::write(RESULT_PATH, json::to_string(&dump) + "\n") {
        Ok(()) => println!("wrote machine-readable results to {RESULT_PATH}"),
        Err(e) => eprintln!("WARN: could not write {RESULT_PATH}: {e}"),
    }
    if fail {
        std::process::exit(1);
    }
}
