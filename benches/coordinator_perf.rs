//! §Perf L3 bench: coordinator overhead and batching leverage.
//!
//! Measures (a) raw executable step latency per bucket, (b) engine
//! steps/s through the full tick path at the same buckets, so the
//! coordinator's overhead is the gap; (c) end-to-end mixed-workload
//! throughput vs max_batch — the continuous-batching payoff curve;
//! (d) router shard scaling: aggregate steps/s for the same multi-dataset
//! workload at 1/2/4 shards per dataset — the speedup the sharded
//! coordinator is supposed to buy on a multi-core host, measured rather
//! than asserted; (e) per-update-kernel engine throughput (DDIM vs
//! PF-ODE vs AB2 host integration) at a fixed batch; (f) an
//! off-bucket active-lane sweep crossing {old single-bucket policy,
//! occupancy planner} × {pipeline depth 1, 2} — occupancy is asserted
//! (it is deterministic), throughput is recorded; (g) the sample
//! cache: a cold vs Zipf-hot workload sweep at cache off/on — the hot
//! replay is deterministic, so a nonzero hit rate (and the engine-step
//! savings it buys) is asserted, throughput and hit rate are dumped; and
//! (h) the v2 transport: a connection-scaling sweep (concurrent
//! connections × reactor count × in-flight ids per connection) driven by
//! a multiplexed bench client over the exported [`Poller`] — the
//! requested-steps/s figure must hold flat as connections grow, and the
//! pipelined (8 ids/conn) cell shows the window-vs-serial payoff in the
//! latency-bound low-connection regime; and (i) schedule quality per NFE
//! budget: fixture Fréchet for linear vs quadratic vs the DP-optimized τ
//! at S ∈ {10, 20, 50} under the optimizer's own eval protocol — the opt
//! column must strictly beat linear at the gated budgets, and the worst
//! opt/linear ratio is tracked against the committed baseline; and (j)
//! overload control: open-loop bursts at 1×/2×/4× the measured S=100
//! service rate, degradation off vs on — with shedding on, best-effort
//! requests drop to S=20/10 under queued-lane pressure and the 4× cell
//! must finish with zero hard-rejects and a bounded p99; with it off, the
//! lane budget hard-rejects the overflow instead; and (k) the
//! observability plane's price: the same multiplexed workload bare vs
//! with the access log + `--trace-sample 16` on — gated at ≤ 5%
//! overhead, with the Prometheus scrape validated on the loaded server.
//!
//! Besides the human-readable tables, every section is dumped to
//! `BENCH_coordinator.json` so the perf trajectory is tracked across PRs
//! instead of scraped from stdout. With `DDIM_BENCH_GATE=1` the run
//! compares its pipelining speedup *ratio* against the committed
//! baseline's and fails on a >30% regression (hardware-portable: both
//! sides of the ratio are measured on the same machine).
//!
//!     cargo bench --bench coordinator_perf
//!     DDIM_BENCH_GATE=1 cargo bench --bench coordinator_perf   # CI gate

#[path = "common.rs"]
mod common;

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use ddim_serve::config::{default_reactors, ServeConfig};
use ddim_serve::coordinator::conn::{ConnEvent, ConnState};
use ddim_serve::coordinator::request::{CacheMode, Priority, Request, RequestBody};
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::{raise_nofile_limit, Engine, Poller, Router, Server};
use ddim_serve::jobj;
use ddim_serve::json::{self, Value};
use ddim_serve::obs::prom::validate_exposition;
use ddim_serve::runtime::{Runtime, StepOutput};
use ddim_serve::sampler::{BatchRunner, SamplerKind};
use ddim_serve::schedule::{
    optimize_tau, optimizer_seed, NoiseMode, OptSchedules, TauKind, EVAL_LANES,
};

const RESULT_PATH: &str = "BENCH_coordinator.json";

fn raw_step_ms(rt: &mut Runtime, ds: &str, bucket: usize, iters: usize) -> f64 {
    let dim = rt.manifest().sample_dim();
    let x = vec![0.1f32; bucket * dim];
    let t = vec![500.0f32; bucket];
    let a_in = vec![0.3f32; bucket];
    let a_out = vec![0.6f32; bucket];
    let sigma = vec![0.0f32; bucket];
    let noise = vec![0.0f32; bucket * dim];
    let mut out = StepOutput::zeros(bucket * dim);
    let exe = rt.executable(ds, bucket).expect("exe");
    // warmup
    exe.run(&x, &t, &a_in, &a_out, &sigma, &noise, &mut out).expect("warm");
    let t0 = Instant::now();
    for _ in 0..iters {
        exe.run(&x, &t, &a_in, &a_out, &sigma, &noise, &mut out).expect("step");
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

/// One bench-client connection in the (h) sweep: the same framing state
/// machine the server reactors use, driven from the bench side.
struct BenchConn {
    stream: TcpStream,
    state: ConnState,
    sent: usize,
    reg_write: bool,
}

fn transport_req_line(conn: usize, k: usize, window: usize, steps: usize) -> String {
    let seed = conn as u64 * 1_000_000 + k as u64;
    if window > 1 {
        format!(
            "{{\"op\":\"generate\",\"dataset\":\"sprites\",\"steps\":{steps},\"eta\":0.0,\
             \"count\":1,\"seed\":{seed},\"cache\":\"bypass\",\"id\":{k}}}"
        )
    } else {
        format!(
            "{{\"op\":\"generate\",\"dataset\":\"sprites\",\"steps\":{steps},\"eta\":0.0,\
             \"count\":1,\"seed\":{seed},\"cache\":\"bypass\"}}"
        )
    }
}

fn flush_bench_conn(c: &mut BenchConn) {
    while c.state.wants_write() {
        match c.stream.write(c.state.pending_write()) {
            Ok(0) => panic!("server closed connection mid-bench"),
            Ok(n) => c.state.consume_written(n),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => panic!("bench write: {e}"),
        }
    }
}

/// Drive `n_conns` multiplexed connections with `window` requests in
/// flight each until every connection has completed `reqs_per_conn`
/// requests; returns the wall seconds of the loaded phase (connection
/// setup excluded).
fn transport_cell(
    addr: SocketAddr,
    n_conns: usize,
    window: usize,
    reqs_per_conn: usize,
    steps: usize,
) -> f64 {
    let poller = Poller::new().expect("bench poller");
    let mut conns = Vec::with_capacity(n_conns);
    for i in 0..n_conns {
        let stream = TcpStream::connect(addr).expect("bench connect");
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).expect("nonblocking");
        poller.add(&stream, i as u64, true, false).expect("poller add");
        conns.push(BenchConn {
            stream,
            state: ConnState::new(1 << 20, 64 << 20),
            sent: 0,
            reg_write: false,
        });
        // pace the connect burst so the listener backlog never overflows
        // (the acceptor drains it between 5 ms sleeps)
        if i % 100 == 99 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    let t0 = Instant::now();
    for (i, c) in conns.iter_mut().enumerate() {
        while c.sent < reqs_per_conn.min(window) {
            let line = transport_req_line(i, c.sent, window, steps);
            c.state.queue_line(&line);
            c.sent += 1;
        }
        flush_bench_conn(c);
        if c.state.wants_write() {
            c.reg_write = true;
            poller.modify(&c.stream, i as u64, true, true).expect("poller mod");
        }
    }

    let total = n_conns * reqs_per_conn;
    let mut received = 0usize;
    let mut events = Vec::with_capacity(256);
    let mut buf = [0u8; 16 * 1024];
    let mut line_events: Vec<ConnEvent> = Vec::new();
    while received < total {
        poller.wait(&mut events, 50).expect("poller wait");
        for ev in events.drain(..) {
            let c = &mut conns[ev.token as usize];
            if ev.writable {
                flush_bench_conn(c);
            }
            if ev.readable {
                loop {
                    match c.stream.read(&mut buf) {
                        Ok(0) => panic!("server closed connection mid-bench"),
                        Ok(n) => c.state.ingest(&buf[..n], &mut line_events),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(e) => panic!("bench read: {e}"),
                    }
                }
                for le in line_events.drain(..) {
                    let ConnEvent::Line(l) = le else {
                        panic!("bench response overlong")
                    };
                    assert!(
                        !l.contains("\"ok\":false"),
                        "bench request rejected: {l}"
                    );
                    received += 1;
                    if c.sent < reqs_per_conn {
                        let line =
                            transport_req_line(ev.token as usize, c.sent, window, steps);
                        c.state.queue_line(&line);
                        c.sent += 1;
                        flush_bench_conn(c);
                    }
                }
            }
            let ww = c.state.wants_write();
            if ww != c.reg_write {
                c.reg_write = ww;
                poller.modify(&c.stream, ev.token, true, ww).expect("poller mod");
            }
        }
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let ds = "sprites";
    let iters = if common::quick() { 3 } else { 20 };
    let gate = std::env::var("DDIM_BENCH_GATE").as_deref() == Ok("1");
    // the committed baseline must be read before this run overwrites it
    let committed: Option<Value> =
        std::fs::read_to_string(RESULT_PATH).ok().and_then(|s| json::parse(&s).ok());
    let baseline_pipelined: Option<f64> = committed.as_ref().and_then(|v| {
        v.get("transport")
            .ok()
            .and_then(|t| t.get("pipelined_speedup").ok()?.as_f64().ok())
    });
    let baseline_tau_ratio: Option<f64> = committed.as_ref().and_then(|v| {
        v.get("tau_quality")
            .ok()
            .and_then(|t| t.get("worst_opt_ratio").ok()?.as_f64().ok())
    });
    let mut sec_raw: Vec<Value> = Vec::new();
    let mut sec_engine: Vec<Value> = Vec::new();
    let mut sec_mixed: Vec<Value> = Vec::new();
    let mut sec_shards: Vec<Value> = Vec::new();
    let mut sec_kernels: Vec<Value> = Vec::new();
    let mut sec_planner: Vec<Value> = Vec::new();

    println!("=== coordinator_perf (a): raw executable latency per bucket ===");
    println!(
        "{:>8} | {:>12} | {:>14} | {:>12}",
        "bucket", "ms/call", "ms/sample-step", "steps/s"
    );
    let buckets = rt.manifest().buckets.clone();
    let mut raw = Vec::new();
    for &b in &buckets {
        let ms = raw_step_ms(&mut rt, ds, b, iters);
        println!(
            "{b:>8} | {ms:>12.2} | {:>14.2} | {:>12.0}",
            ms / b as f64,
            1e3 / ms * b as f64
        );
        sec_raw.push(jobj![
            ("bucket", b),
            ("ms_per_call", ms),
            ("steps_per_s", 1e3 / ms * b as f64),
        ]);
        raw.push(ms);
    }

    println!("\n=== coordinator_perf (b): engine tick path vs raw executable ===");
    println!(
        "{:>10} | {:>14} | {:>14} | {:>10}",
        "max_batch", "engine steps/s", "raw steps/s", "overhead"
    );
    for (i, &b) in buckets.iter().enumerate() {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: b,
            max_lanes: 4 * b,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        // saturate with enough identical lanes to keep the bucket full
        let steps = if common::quick() { 5 } else { 25 };
        for k in 0..4 {
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode: NoiseMode::Eta(0.0),
                    tau: TauKind::Linear,
                    sampler: SamplerKind::Ddim,
                    body: RequestBody::Generate { count: b, seed: k },
                    return_images: false,
                    cache: CacheMode::Use,
                    qos: Default::default(),
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        let engine_sps = m.steps_executed as f64 / wall;
        let raw_sps = 1e3 / raw[i] * b as f64;
        println!(
            "{b:>10} | {engine_sps:>14.0} | {raw_sps:>14.0} | {:>9.1}%",
            (1.0 - engine_sps / raw_sps) * 100.0
        );
        sec_engine.push(jobj![
            ("max_batch", b),
            ("engine_steps_per_s", engine_sps),
            ("raw_steps_per_s", raw_sps),
            ("overhead_frac", 1.0 - engine_sps / raw_sps),
            ("occupancy", m.occupancy()),
        ]);
    }

    println!("\n=== coordinator_perf (c): mixed heterogeneous workload vs max_batch ===");
    println!(
        "{:>10} | {:>10} | {:>12} | {:>10} | {:>10}",
        "max_batch", "wall s", "steps/s", "occupancy", "p95 ms"
    );
    let n_req = if common::quick() { 8 } else { 24 };
    for &b in &buckets {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: b,
            max_lanes: 64,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        // heterogeneous mix: short interactive + long batch + stochastic
        for k in 0..n_req {
            let (steps, mode, count) = match k % 4 {
                0 => (10, NoiseMode::Eta(0.0), 1),
                1 => (20, NoiseMode::Eta(0.0), 4),
                2 => (50, NoiseMode::Eta(0.0), 1),
                _ => (20, NoiseMode::Eta(1.0), 2),
            };
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode,
                    tau: TauKind::Linear,
                    sampler: SamplerKind::Ddim,
                    body: RequestBody::Generate { count, seed: k as u64 },
                    return_images: false,
                    cache: CacheMode::Use,
                    qos: Default::default(),
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        println!(
            "{b:>10} | {wall:>10.2} | {:>12.0} | {:>10.2} | {:>10.0}",
            m.steps_executed as f64 / wall,
            m.occupancy(),
            m.latency_p95_s * 1e3
        );
        sec_mixed.push(jobj![
            ("max_batch", b),
            ("wall_s", wall),
            ("steps_per_s", m.steps_executed as f64 / wall),
            ("occupancy", m.occupancy()),
            ("latency_p50_ms", m.latency_p50_s * 1e3),
            ("latency_p95_ms", m.latency_p95_s * 1e3),
        ]);
    }
    println!("\n=== coordinator_perf (d): router shard scaling (multi-dataset workload) ===");
    // 4 logical request streams cycling over every dataset the artifact
    // bundle ships; each sweep re-runs the identical workload with more
    // shards per dataset. On a 4-core host 1 -> 4 shards should exceed
    // 1.5x aggregate steps/s (acceptance criterion for the refactor).
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    let streams: Vec<String> =
        (0..4).map(|i| datasets[i % datasets.len()].clone()).collect();
    let n_req = if common::quick() { 8 } else { 32 };
    let steps = if common::quick() { 5 } else { 20 };
    println!(
        "{:>8} | {:>8} | {:>10} | {:>12} | {:>10} | {:>10}",
        "shards", "total", "wall s", "steps/s", "p95 ms", "speedup"
    );
    let mut base_sps = 0.0f64;
    for &shards in &[1usize, 2, 4] {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: streams[0].clone(),
            max_batch: 8,
            max_lanes: 32,
            queue_capacity: 1024,
            shards,
            ..Default::default()
        };
        let router = Router::start(cfg).expect("router");
        // prewarm every pool so bring-up + executable compilation (both
        // scale with shard count) stay out of the timed region
        for ds in datasets.iter() {
            router.prewarm(ds).expect("prewarm");
        }
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n_req);
        for k in 0..n_req {
            pending.push(router.submit(Request {
                dataset: streams[k % streams.len()].clone(),
                steps,
                mode: if k % 4 == 3 { NoiseMode::Eta(1.0) } else { NoiseMode::Eta(0.0) },
                tau: TauKind::Linear,
                sampler: SamplerKind::Ddim,
                body: RequestBody::Generate { count: 2 + (k % 3), seed: k as u64 },
                return_images: false,
                cache: CacheMode::Use,
                qos: Default::default(),
            }));
        }
        for rx in pending {
            let resp = rx.recv().expect("response");
            if let ddim_serve::coordinator::ResponseBody::Error { message } = &resp.body {
                panic!("request failed: {message}");
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (agg, per_shard) = router.aggregate();
        let sps = agg.steps_executed as f64 / wall;
        if shards == 1 {
            base_sps = sps;
        }
        println!(
            "{shards:>8} | {:>8} | {wall:>10.2} | {sps:>12.0} | {:>10.0} | {:>9.2}x",
            per_shard.len(),
            agg.latency_p95_s * 1e3,
            if base_sps > 0.0 { sps / base_sps } else { 1.0 }
        );
        sec_shards.push(jobj![
            ("shards_per_dataset", shards),
            ("total_shards", per_shard.len()),
            ("wall_s", wall),
            ("steps_per_s", sps),
            ("latency_p50_ms", agg.latency_p50_s * 1e3),
            ("latency_p95_ms", agg.latency_p95_s * 1e3),
            ("occupancy", agg.occupancy()),
            ("speedup_vs_1", if base_sps > 0.0 { sps / base_sps } else { 1.0 }),
        ]);
        router.shutdown();
    }

    println!("\n=== coordinator_perf (e): per-update-kernel engine throughput ===");
    // same model, same executable calls; the delta is the host-side
    // integration cost of PF-ODE / AB2 vs committing the fused x_prev
    println!(
        "{:>8} | {:>10} | {:>12} | {:>10}",
        "kernel", "wall s", "steps/s", "p95 ms"
    );
    let steps = if common::quick() { 5 } else { 20 };
    let n_req = if common::quick() { 4 } else { 12 };
    for kernel in SamplerKind::ALL {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: 8,
            max_lanes: 64,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        for k in 0..n_req {
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode: NoiseMode::Eta(0.0),
                    tau: TauKind::Linear,
                    sampler: kernel,
                    body: RequestBody::Generate { count: 2, seed: k },
                    return_images: false,
                    cache: CacheMode::Use,
                    qos: Default::default(),
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        let sps = m.steps_executed as f64 / wall;
        println!(
            "{:>8} | {wall:>10.2} | {sps:>12.0} | {:>10.0}",
            kernel.label(),
            m.latency_p95_s * 1e3
        );
        assert_eq!(
            m.kernel_steps[kernel.index()],
            m.steps_executed,
            "every step should be accounted to the requested kernel"
        );
        sec_kernels.push(jobj![
            ("kernel", kernel.label()),
            ("wall_s", wall),
            ("steps_per_s", sps),
            ("occupancy", m.occupancy()),
            ("latency_p50_ms", m.latency_p50_s * 1e3),
            ("latency_p95_ms", m.latency_p95_s * 1e3),
        ]);
    }

    println!("\n=== coordinator_perf (f): occupancy planner × pipelined executor ===");
    // Off-bucket active-lane counts (nothing in {1,2,4,8,16} fits 9/17/33
    // exactly) under a mixed-kernel workload, crossing the batch-formation
    // policy (max_padding_waste 1.0 = old single-bucket, 0.25 = planner)
    // with pipeline depth 1 (serial) and 2 (executor thread). Occupancy and
    // padding waste are scheduling arithmetic — deterministic, asserted.
    // Throughput is wall-clock — printed and dumped, not asserted.
    println!(
        "{:>6} | {:>8} | {:>6} | {:>10} | {:>10} | {:>6} | {:>9} | {:>8} | {:>8}",
        "lanes", "policy", "depth", "steps/s", "occupancy", "waste", "sub/tick", "overlap", "speedup"
    );
    let steps = if common::quick() { 4 } else { 12 };
    for &lanes in &[9usize, 17, 33] {
        let mut occ_single = 0.0f64;
        let mut sps_depth1 = 0.0f64;
        for &(policy, waste) in &[("single", 1.0f64), ("planner", 0.25)] {
            for &depth in &[1usize, 2] {
                let cfg = ServeConfig {
                    artifact_root: common::artifacts_root(),
                    dataset: ds.into(),
                    max_batch: lanes,
                    max_lanes: 64,
                    queue_capacity: 1024,
                    max_padding_waste: waste,
                    pipeline_depth: depth,
                    ..Default::default()
                };
                let mut engine = Engine::new(cfg).expect("engine");
                engine.warmup().expect("warmup");
                // mixed-kernel fill: exactly `lanes` equal-length lanes so
                // the active count (and thus the tick plan) stays constant
                let third = lanes / 3;
                for (kernel, count, seed) in [
                    (SamplerKind::Ddim, lanes - 2 * third, 1u64),
                    (SamplerKind::PfOde, third, 2),
                    (SamplerKind::Ab2, third, 3),
                ] {
                    engine
                        .submit(Request {
                            dataset: ds.into(),
                            steps,
                            mode: NoiseMode::Eta(0.0),
                            tau: TauKind::Linear,
                            sampler: kernel,
                            body: RequestBody::Generate { count, seed },
                            return_images: false,
                            cache: CacheMode::Use,
                            qos: Default::default(),
                        })
                        .expect("submit");
                }
                let t0 = Instant::now();
                engine.run_until_idle().expect("drain");
                let wall = t0.elapsed().as_secs_f64();
                let m = engine.metrics();
                let sps = m.steps_executed as f64 / wall;
                assert_eq!(m.steps_executed, (lanes * steps) as u64);
                if policy == "single" && depth == 1 {
                    occ_single = m.occupancy();
                }
                if policy == "planner" && depth == 1 {
                    sps_depth1 = sps;
                    // deterministic scheduling arithmetic: the planner may
                    // never lose occupancy to the single-bucket policy
                    assert!(
                        m.occupancy() + 1e-9 >= occ_single,
                        "planner occupancy {} < single-bucket {occ_single} at {lanes} lanes",
                        m.occupancy()
                    );
                }
                let speedup = if policy == "planner" && depth == 2 && sps_depth1 > 0.0 {
                    sps / sps_depth1
                } else {
                    1.0
                };
                println!(
                    "{lanes:>6} | {policy:>8} | {depth:>6} | {sps:>10.0} | {:>10.2} | {:>6.2} | {:>9.2} | {:>8.2} | {speedup:>7.2}x",
                    m.occupancy(),
                    m.padding_waste(),
                    m.sub_batches_per_tick(),
                    m.overlap_frac(),
                );
                sec_planner.push(jobj![
                    ("active_lanes", lanes),
                    ("policy", policy),
                    ("pipeline_depth", depth),
                    ("wall_s", wall),
                    ("steps_per_s", sps),
                    ("occupancy", m.occupancy()),
                    ("padding_waste", m.padding_waste()),
                    ("sub_batches", m.sub_batches),
                    ("sub_batches_per_tick", m.sub_batches_per_tick()),
                    ("overlap_frac", m.overlap_frac()),
                ]);
            }
        }
    }

    println!("\n=== coordinator_perf (g): sample cache — cold vs Zipf-hot, off vs on ===");
    // A cold workload (every request a unique identity) and a Zipf-hot one
    // (identities drawn from a finite pool, web-traffic skew), each
    // replayed sequentially through a router with the cache off and on.
    // The replay is deterministic per workload seed, so the hit counts are
    // scheduling arithmetic, not timing — asserted, while throughput is
    // recorded. "req steps/s" counts the steps *requested* (cache-served
    // work included); "engine steps/s" counts steps actually executed —
    // the gap is the saved FLOPs.
    println!(
        "{:>10} | {:>6} | {:>10} | {:>13} | {:>14} | {:>9} | {:>6} | {:>6}",
        "workload", "cache", "wall s", "req steps/s", "engine steps/s", "hit rate", "hits", "coal"
    );
    let dim = rt.manifest().sample_dim();
    let n_req = if common::quick() { 64 } else { 192 };
    let mut sec_cache: Vec<Value> = Vec::new();
    for (wl_name, workload) in [
        ("cold", ddim_serve::workload::Workload::standard(ds, 1000.0)),
        ("zipf_hot", ddim_serve::workload::Workload::zipf(ds, 1000.0, dim, 8, 1.1)),
    ] {
        for cache_on in [false, true] {
            let cfg = ServeConfig {
                artifact_root: common::artifacts_root(),
                dataset: ds.into(),
                max_batch: 8,
                max_lanes: 64,
                queue_capacity: 1024,
                cache_enabled: cache_on,
                coalesce_enabled: cache_on,
                ..Default::default()
            };
            let router = Router::start(cfg).expect("router");
            router.prewarm(ds).expect("prewarm");
            let plan = workload.generate(n_req, 42);
            let requested_steps: usize =
                plan.iter().map(|(_, r)| r.steps * r.lane_count()).sum();
            let t0 = Instant::now();
            for (_, req) in plan {
                let resp = router.call(req).expect("response");
                if let ddim_serve::coordinator::ResponseBody::Error { message } = &resp.body {
                    panic!("request failed: {message}");
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let (agg, _) = router.aggregate();
            let cm = router.cache().metrics();
            // deterministic replay: a hot pool of 8 identities over 6
            // classes is pigeonhole-guaranteed to repeat within 64
            // sequential requests — the cache MUST convert those into hits
            if cache_on && wl_name == "zipf_hot" {
                assert!(
                    cm.hits > 0,
                    "Zipf-hot workload with the cache on produced no hits: {cm:?}"
                );
                assert!(
                    agg.steps_executed < requested_steps as u64,
                    "cache hits must save engine steps"
                );
            }
            if !cache_on {
                assert_eq!(cm.hits, 0, "cache off must not hit");
            }
            println!(
                "{wl_name:>10} | {:>6} | {wall:>10.2} | {:>13.0} | {:>14.0} | {:>9.2} | {:>6} | {:>6}",
                if cache_on { "on" } else { "off" },
                requested_steps as f64 / wall,
                agg.steps_executed as f64 / wall,
                cm.hit_rate(),
                cm.hits,
                cm.coalesced_waiters,
            );
            sec_cache.push(jobj![
                ("workload", wl_name),
                ("cache", if cache_on { "on" } else { "off" }),
                ("requests", n_req),
                ("wall_s", wall),
                ("requested_steps_per_s", requested_steps as f64 / wall),
                ("engine_steps_per_s", agg.steps_executed as f64 / wall),
                ("engine_steps_executed", agg.steps_executed),
                ("requested_steps", requested_steps),
                ("hit_rate", cm.hit_rate()),
                ("hits", cm.hits),
                ("misses", cm.misses),
                ("coalesced_waiters", cm.coalesced_waiters),
                ("cache_bytes", cm.bytes),
                ("latency_p50_ms", agg.latency_p50_s * 1e3),
                ("latency_p95_ms", agg.latency_p95_s * 1e3),
            ]);
            router.shutdown();
        }
    }

    println!("\n=== coordinator_perf (h): transport connection scaling (v2 reactors) ===");
    // Concurrent connections × reactors × in-flight window, all cache-
    // bypass single-lane requests so every one exercises the full
    // transport → router → engine → transport path. The low-connection
    // cell is the latency-bound regime where pipelining pays (window 8
    // fills the batch a serial client leaves half-empty and hides RTT);
    // at high connection counts the engine saturates either way and the
    // sweep instead checks the event loop holds throughput flat.
    let mut conn_list: Vec<usize> =
        if common::quick() { vec![8, 32] } else { vec![8, 64, 256, 1024] };
    // fixed per-cell workload (split across however many connections) so
    // every cell runs long enough to time; floor of 8/conn keeps the
    // window-8 cells honest at high connection counts
    let req_target = if common::quick() { 256 } else { 2048 };
    let tr_steps = 4usize;
    let nofile = raise_nofile_limit();
    // every bench connection is two fds in this process (client + server
    // end), plus reactor wake pairs, fixtures, and headroom
    let max_conns = (nofile.saturating_sub(256) / 2) as usize;
    let before = conn_list.len();
    conn_list.retain(|&c| c <= max_conns);
    if conn_list.len() < before {
        println!(
            "NOTE: fd limit {nofile} supports only {max_conns} concurrent \
             connections — dropped the larger sweep cells (no silent caps)"
        );
    }
    let mut reactor_list = vec![1usize, default_reactors()];
    reactor_list.dedup();
    println!(
        "{:>6} | {:>8} | {:>7} | {:>10} | {:>10} | {:>14}",
        "conns", "reactors", "window", "wall s", "req/s", "req steps/s"
    );
    let mut sec_transport: Vec<Value> = Vec::new();
    let mut tr_sps: HashMap<(usize, usize, usize), f64> = HashMap::new();
    for &conns in &conn_list {
        let reqs_per_conn = (req_target / conns).max(8);
        for &reactors in &reactor_list {
            for &window in &[1usize, 8] {
                let cfg = ServeConfig {
                    artifact_root: common::artifacts_root(),
                    dataset: ds.into(),
                    listen: "127.0.0.1:0".into(),
                    max_batch: 16,
                    max_lanes: 64,
                    queue_capacity: 16384,
                    reactors,
                    ..Default::default()
                };
                let server = Server::start(cfg).expect("server");
                // one warm round trip keeps engine warmup out of the cell
                let mut warm = Client::connect(server.addr()).expect("warm client");
                let r = warm
                    .roundtrip(&json::parse(&transport_req_line(0, 0, 1, tr_steps)).unwrap())
                    .expect("warm roundtrip");
                assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
                drop(warm);
                let wall =
                    transport_cell(server.addr(), conns, window, reqs_per_conn, tr_steps);
                server.shutdown();
                let n_req = (conns * reqs_per_conn) as f64;
                let sps = n_req * tr_steps as f64 / wall;
                println!(
                    "{conns:>6} | {reactors:>8} | {window:>7} | {wall:>10.3} | {:>10.0} | {sps:>14.0}",
                    n_req / wall
                );
                tr_sps.insert((conns, reactors, window), sps);
                sec_transport.push(jobj![
                    ("conns", conns),
                    ("reactors", reactors),
                    ("window", window),
                    ("requests", conns * reqs_per_conn),
                    ("wall_s", wall),
                    ("req_per_s", n_req / wall),
                    ("requested_steps_per_s", sps),
                ]);
            }
        }
    }
    let nr = *reactor_list.last().unwrap();
    let lo = conn_list[0];
    let pipelined_speedup = tr_sps[&(lo, nr, 8)] / tr_sps[&(lo, nr, 1)];
    // connection scaling over the engine-saturated cells (the lowest conn
    // count is the latency-bound regime and is excluded by construction)
    let saturated = &conn_list[1..];
    let conn_scaling_ratio = if saturated.len() >= 2 {
        tr_sps[&(*saturated.last().unwrap(), nr, 1)] / tr_sps[&(saturated[0], nr, 1)]
    } else {
        1.0
    };
    println!(
        "\npipelined speedup at {lo} conns (window 8 vs 1, {nr} reactors): {pipelined_speedup:.2}x"
    );
    if saturated.len() >= 2 {
        println!(
            "connection scaling {} -> {} conns (window 1): {:.2}x",
            saturated[0],
            saturated.last().unwrap(),
            conn_scaling_ratio
        );
    }
    if gate {
        if let Some(base) = baseline_pipelined {
            let floor = 0.7 * base;
            assert!(
                pipelined_speedup >= floor,
                "transport pipelining regression: speedup {pipelined_speedup:.2}x fell \
                 below 70% of the committed baseline {base:.2}x (floor {floor:.2}x)"
            );
            println!("gate OK: {pipelined_speedup:.2}x >= 0.7 * baseline {base:.2}x");
        } else {
            println!("gate: no committed transport baseline in {RESULT_PATH}; skipping");
        }
    }
    let sec_transport_obj = jobj![
        ("pipelined_speedup", pipelined_speedup),
        ("pipelined_speedup_conns", lo),
        ("conn_scaling_ratio", conn_scaling_ratio),
        ("reactors_default", nr),
        ("sweep", Value::Arr(sec_transport)),
    ];

    println!("\n=== coordinator_perf (i): schedule quality per NFE budget ===");
    println!(
        "{:>8} | {:>4} | {:>10} | {:>10} | {:>10} | {:>8}",
        "dataset", "S", "linear", "quadratic", "opt", "opt/lin"
    );
    // same eval protocol as the optimizer's final stage (EVAL_LANES lanes,
    // optimizer_seed(ds, S, 2), η = 0): the opt cell is the committed
    // schedule re-scored under the exact objective it was selected by, so
    // opt <= linear holds by construction, not by luck
    let opt_registry = {
        let m = rt.manifest();
        OptSchedules::load(&m.root, ddim_serve::cache::manifest_digest(m))
    };
    let tau_datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    let mut sec_tauq: Vec<Value> = Vec::new();
    let mut worst_opt_ratio: f64 = 0.0;
    for ds_name in &tau_datasets {
        let reference = common::reference_for(&rt, ds_name);
        let mut runner = BatchRunner::new(&rt, ds_name, EVAL_LANES).expect("runner");
        for s in [10usize, 20, 50] {
            let seed = optimizer_seed(ds_name, s, 2);
            let eta0 = NoiseMode::Eta(0.0);
            let lin = common::fid_cell(
                &mut rt, &mut runner, &reference, TauKind::Linear, s, eta0, EVAL_LANES, seed,
            );
            let quad = common::fid_cell(
                &mut rt, &mut runner, &reference, TauKind::Quadratic, s, eta0, EVAL_LANES, seed,
            );
            // prefer the bundle's committed schedule; optimize in-place when
            // the artifact tree predates `ddim-serve optimize-tau`
            let tau = match opt_registry.get(ds_name, s) {
                Some(sched) => sched.tau.clone(),
                None => optimize_tau(&mut rt, ds_name, s).expect("optimize").schedule.tau,
            };
            let o = common::fid_cell_tau(
                &mut rt, &mut runner, &reference, tau, eta0, EVAL_LANES, seed,
            );
            let ratio = o / lin;
            println!(
                "{ds_name:>8} | {s:>4} | {lin:>10.4} | {quad:>10.4} | {o:>10.4} | {ratio:>8.4}"
            );
            if s <= 20 {
                worst_opt_ratio = worst_opt_ratio.max(ratio);
                if gate {
                    assert!(
                        o < lin,
                        "optimized tau must strictly beat linear at {ds_name}/S={s}: \
                         {o:.4} vs {lin:.4}"
                    );
                }
            }
            sec_tauq.push(jobj![
                ("dataset", ds_name.clone()),
                ("steps", s),
                ("n", EVAL_LANES),
                ("linear", lin),
                ("quadratic", quad),
                ("opt", o),
                ("opt_over_linear", ratio),
            ]);
        }
    }
    println!("worst opt/linear ratio over the gated budgets (S <= 20): {worst_opt_ratio:.4}");
    if gate {
        if let Some(base) = baseline_tau_ratio {
            let ceiling = (base * 1.3).min(1.0);
            assert!(
                worst_opt_ratio <= ceiling,
                "tau-quality regression: worst opt/linear ratio {worst_opt_ratio:.4} exceeds \
                 ceiling {ceiling:.4} (committed baseline {base:.4} * 1.3, capped at 1.0)"
            );
            println!("gate OK: {worst_opt_ratio:.4} <= ceiling {ceiling:.4}");
        } else {
            println!("gate: no committed tau_quality baseline in {RESULT_PATH}; skipping");
        }
    }
    let sec_tauq_obj = jobj![
        ("worst_opt_ratio", worst_opt_ratio),
        ("gated_steps_max", 20usize),
        ("cells", Value::Arr(sec_tauq)),
    ];

    println!("\n=== coordinator_perf (j): overload — 1x/2x/4x bursts, degradation off vs on ===");
    // Open-loop offered load at multiples of the *measured* full-budget
    // service rate, all best-effort S=100 requests against one small shard
    // (8 lanes, 48-lane queue budget). With degradation on, queued-lane
    // pressure rewrites arrivals to S=20/10 (§4.3: fewer DDIM steps, a
    // quality dial rather than a failure), so capacity rises ~5x and the
    // 4x burst drains without hard-rejecting; with it off, the lane budget
    // sheds the overflow as typed rejects. Every completion is counted
    // exactly once; p50/p99 are client-observed (arrival-anchored).
    let ov_steps = 100usize;
    let ov_cfg = |degrade: bool| ServeConfig {
        artifact_root: common::artifacts_root(),
        dataset: ds.into(),
        max_batch: 8,
        max_lanes: 8,
        queue_capacity: 256,
        queue_lane_cap: 48,
        degrade_enabled: degrade,
        degrade_mid: 1.0,
        degrade_high: 2.0,
        ..Default::default()
    };
    let ov_req = |seed: u64| {
        let mut r = Request {
            dataset: ds.into(),
            steps: ov_steps,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Linear,
            sampler: SamplerKind::Ddim,
            body: RequestBody::Generate { count: 1, seed },
            return_images: false,
            cache: CacheMode::Bypass,
            qos: Default::default(),
        };
        r.qos.priority = Priority::BestEffort;
        r
    };
    // calibrate: closed-loop full-budget throughput with shedding off —
    // the sweep below offers multiples of this measured rate
    let cal_n = if common::quick() { 8 } else { 16 };
    let service_rate = {
        let router = Router::start(ov_cfg(false)).expect("router");
        router.prewarm(ds).expect("prewarm");
        let t0 = Instant::now();
        let pending: Vec<_> =
            (0..cal_n).map(|k| router.submit(ov_req(900_000 + k as u64))).collect();
        for rx in pending {
            rx.recv().expect("calibration response");
        }
        let rate = cal_n as f64 / t0.elapsed().as_secs_f64();
        router.shutdown();
        rate
    };
    println!("calibrated S={ov_steps} service rate: {service_rate:.1} req/s");
    println!(
        "{:>6} | {:>8} | {:>6} | {:>8} | {:>9} | {:>10} | {:>10}",
        "mult", "degrade", "ok", "rejects", "degraded", "p50 ms", "p99 ms"
    );
    let ov_n = if common::quick() { 32 } else { 96 };
    let mut sec_overload: Vec<Value> = Vec::new();
    let mut ov_p99: HashMap<(usize, bool), f64> = HashMap::new();
    let mut ov_rejects: HashMap<(usize, bool), usize> = HashMap::new();
    let mut ov_degraded: HashMap<(usize, bool), usize> = HashMap::new();
    for &mult in &[1usize, 2, 4] {
        for degrade in [false, true] {
            let router = Router::start(ov_cfg(degrade)).expect("router");
            router.prewarm(ds).expect("prewarm");
            let (tx, rx) = std::sync::mpsc::channel();
            let offered = mult as f64 * service_rate;
            let t0 = Instant::now();
            for k in 0..ov_n {
                let due =
                    t0 + std::time::Duration::from_secs_f64(k as f64 / offered);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                let mut req = ov_req((mult * 100_000 + k) as u64);
                req.qos.arrived = Some(Instant::now());
                let tx = tx.clone();
                router.submit_with(
                    req,
                    Box::new(move |resp| {
                        let _ = tx.send(resp);
                    }),
                    None,
                );
            }
            drop(tx);
            let responses: Vec<_> = rx.iter().collect();
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(responses.len(), ov_n, "every request answered exactly once");
            let mut lat: Vec<f64> = Vec::new();
            let mut rejects = 0usize;
            let mut degraded_n = 0usize;
            for resp in &responses {
                match &resp.body {
                    ddim_serve::coordinator::ResponseBody::Reject(r) => {
                        assert!(
                            !r.message.is_empty(),
                            "typed reject must carry a message"
                        );
                        rejects += 1;
                    }
                    ddim_serve::coordinator::ResponseBody::Error { message } => {
                        panic!("overload bench hit a non-typed error: {message}")
                    }
                    _ => {
                        if let Some((from, to)) = resp.degraded {
                            assert!(
                                to < from,
                                "degraded record must shrink the budget: {from} -> {to}"
                            );
                            degraded_n += 1;
                        }
                        lat.push(resp.latency_s);
                    }
                }
            }
            lat.sort_by(|a, b| a.total_cmp(b));
            let q = |f: f64| -> f64 {
                if lat.is_empty() {
                    0.0
                } else {
                    lat[((lat.len() - 1) as f64 * f).round() as usize]
                }
            };
            let (p50, p99) = (q(0.5), q(0.99));
            let (agg, _) = router.aggregate();
            println!(
                "{mult:>5}x | {:>8} | {:>6} | {rejects:>8} | {degraded_n:>9} | {:>10.0} | {:>10.0}",
                if degrade { "on" } else { "off" },
                lat.len(),
                p50 * 1e3,
                p99 * 1e3,
            );
            ov_p99.insert((mult, degrade), p99);
            ov_rejects.insert((mult, degrade), rejects);
            ov_degraded.insert((mult, degrade), degraded_n);
            sec_overload.push(jobj![
                ("multiplier", mult),
                ("degrade", if degrade { "on" } else { "off" }),
                ("offered_per_s", offered),
                ("requests", ov_n),
                ("completed", lat.len()),
                ("rejects", rejects),
                ("degraded", degraded_n),
                ("wall_s", wall),
                ("latency_p50_ms", p50 * 1e3),
                ("latency_p99_ms", p99 * 1e3),
                ("queue_rejected_items", agg.queue_rejected_items),
                ("queue_rejected_lanes", agg.queue_rejected_lanes),
                ("requests_degraded", agg.requests_degraded),
            ]);
            router.shutdown();
        }
    }
    if gate {
        // self-contained gate (no committed baseline needed): shedding
        // must absorb the 4x burst without hard rejects, must actually
        // have degraded something, and must keep p99 bounded relative to
        // the 1x cell (generous factor: the pre-shedding S=100 cohort
        // still has to drain through the queue)
        assert_eq!(
            ov_rejects[&(4, true)],
            0,
            "4x burst with degradation on must not hard-reject"
        );
        assert!(
            ov_degraded[&(4, true)] > 0,
            "4x burst with degradation on produced no degraded responses"
        );
        let (p99_1, p99_4) = (ov_p99[&(1, true)], ov_p99[&(4, true)]);
        let ceiling = (25.0 * p99_1).max(p99_1 + 5.0);
        assert!(
            p99_4 <= ceiling,
            "4x-burst p99 {p99_4:.3}s not bounded: ceiling {ceiling:.3}s (1x p99 {p99_1:.3}s)"
        );
        println!(
            "gate OK: 4x/on rejects=0, degraded={}, p99 {p99_4:.3}s <= {ceiling:.3}s",
            ov_degraded[&(4, true)]
        );
    }
    let sec_overload_obj = jobj![
        ("service_rate_req_per_s", service_rate),
        ("steps_full", ov_steps),
        ("cells", Value::Arr(sec_overload)),
    ];

    println!("\n=== coordinator_perf (k): observability — bare vs access-log + trace-sample 16 ===");
    // Same multiplexed workload twice: everything off, then the access
    // log plus `--trace-sample 16` on. The delta is the whole price of
    // the observability plane at its production setting (the log write
    // is a bounded try_send off the completion path; untraced requests
    // skip all span clock reads). Best-of-reps on both sides damps
    // scheduler noise so the gate measures the plane, not the machine.
    let obs_dir = std::env::temp_dir().join(format!("ddim_bench_obs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&obs_dir);
    std::fs::create_dir_all(&obs_dir).expect("obs scratch dir");
    let obs_log = obs_dir.join("access.log");
    let obs_steps = 20usize;
    let obs_conns = 4usize;
    let obs_window = 8usize;
    let obs_reqs = if common::quick() { 32 } else { 128 };
    let obs_reps = if common::quick() { 2 } else { 3 };
    let obs_cfg = |instrumented: bool| {
        let mut c = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            listen: "127.0.0.1:0".into(),
            max_batch: 8,
            ..Default::default()
        };
        if instrumented {
            c.access_log = obs_log.to_str().expect("utf8 path").to_string();
            c.trace_sample = 16;
        }
        c
    };
    let obs_run = |instrumented: bool| -> (f64, usize, String) {
        let server = Server::start(obs_cfg(instrumented)).expect("obs server");
        let _ = transport_cell(server.addr(), 1, obs_window, 4, obs_steps); // warmup
        let mut best = f64::MAX;
        for _ in 0..obs_reps {
            best = best.min(transport_cell(
                server.addr(),
                obs_conns,
                obs_window,
                obs_reqs / obs_conns,
                obs_steps,
            ));
        }
        // scrape the loaded server before teardown; validated below
        let mut c = Client::connect(server.addr()).expect("scrape client");
        let r = c
            .roundtrip(&jobj![("op", "metrics"), ("format", "prometheus")])
            .expect("scrape roundtrip");
        let scrape = r
            .get("prometheus")
            .expect("prometheus field")
            .as_str()
            .expect("scrape is a string")
            .to_string();
        server.shutdown();
        let log_lines = if instrumented {
            std::fs::read_to_string(&obs_log).map(|t| t.lines().count()).unwrap_or(0)
        } else {
            0
        };
        (best, log_lines, scrape)
    };
    let (bare_wall, _, bare_scrape) = obs_run(false);
    let (inst_wall, obs_log_lines, inst_scrape) = obs_run(true);
    let obs_total_steps = (obs_reqs * obs_steps) as f64;
    let bare_sps = obs_total_steps / bare_wall;
    let inst_sps = obs_total_steps / inst_wall;
    let obs_overhead = 1.0 - inst_sps / bare_sps;
    for (label, scrape) in [("bare", &bare_scrape), ("instrumented", &inst_scrape)] {
        if let Err(e) = validate_exposition(scrape) {
            panic!("{label} Prometheus scrape failed validation: {e}");
        }
    }
    println!(
        "{:>14} | {:>12} | {:>10}",
        "config", "steps/s", "log lines"
    );
    println!("{:>14} | {bare_sps:>12.0} | {:>10}", "bare", "-");
    println!("{:>14} | {inst_sps:>12.0} | {obs_log_lines:>10}", "log+trace/16");
    println!(
        "observability overhead: {:.1}% (access log + 1/16 span sampling)",
        obs_overhead * 100.0
    );
    assert!(obs_log_lines > 0, "instrumented run produced no access-log lines");
    if gate {
        assert!(
            obs_overhead <= 0.05,
            "observability overhead {:.1}% exceeds the 5% budget \
             (bare {bare_sps:.0} steps/s -> instrumented {inst_sps:.0})",
            obs_overhead * 100.0
        );
        println!("gate OK: overhead {:.1}% <= 5%, scrape validated", obs_overhead * 100.0);
    }
    let _ = std::fs::remove_dir_all(&obs_dir);
    let sec_obs_obj = jobj![
        ("requests", obs_reqs),
        ("steps", obs_steps),
        ("connections", obs_conns),
        ("window", obs_window),
        ("trace_sample", 16usize),
        ("bare_steps_per_s", bare_sps),
        ("instrumented_steps_per_s", inst_sps),
        ("overhead_frac", obs_overhead),
        ("access_log_lines", obs_log_lines),
        ("scrape_bytes", inst_scrape.len()),
    ];

    let dump = jobj![
        ("bench", "coordinator_perf"),
        ("quick", common::quick()),
        ("raw_latency", Value::Arr(sec_raw)),
        ("engine_vs_raw", Value::Arr(sec_engine)),
        ("mixed_workload", Value::Arr(sec_mixed)),
        ("shard_scaling", Value::Arr(sec_shards)),
        ("update_kernels", Value::Arr(sec_kernels)),
        ("planner_pipeline", Value::Arr(sec_planner)),
        ("cache", Value::Arr(sec_cache)),
        ("transport", sec_transport_obj),
        ("tau_quality", sec_tauq_obj),
        ("overload", sec_overload_obj),
        ("observability", sec_obs_obj),
    ];
    match std::fs::write(RESULT_PATH, json::to_string(&dump) + "\n") {
        Ok(()) => println!("\nwrote machine-readable results to {RESULT_PATH}"),
        Err(e) => eprintln!("\nWARN: could not write {RESULT_PATH}: {e}"),
    }

    println!("\ninterpretation: overhead column (b) is the coordinator tax (§Perf target < 5%);\ncurve (c) shows continuous batching converting batch capacity into steps/s at near-constant p95;\nsweep (d) is the sharding payoff — aggregate steps/s should scale with shards until cores saturate;\ntable (e) prices the host-side PF-ODE/AB2 integration against the fused DDIM commit;\nsweep (f) shows the planner converting padded FLOPs into occupancy at off-bucket lane counts,\nand depth-2 pipelining overlapping pack/advance with device time (speedup vs planner depth 1);\nsweep (g) shows the sample cache converting repeated identities into served-without-executing\nrequests — the req-vs-engine steps/s gap on the Zipf-hot row is pure saved FLOPs;\nsweep (h) is the v2 transport: requested steps/s must hold flat as connections grow\n(the reactors, not threads-per-conn, carry the fan-in) and the pipelined window shows\nits >= 2x payoff in the latency-bound low-connection regime;\ntable (i) prices schedule choice at a fixed NFE budget — the DP-optimized tau buys the\nsame sample count a strictly lower Frechet than either closed-form grid;\nsweep (j) is the overload story: DDIM's quality/steps dial converts a 4x burst from\nhard-rejects (degradation off) into degraded-but-answered responses with bounded p99;\nrow (k) prices the observability plane — access log + 1/16 span sampling must keep\n>= 95% of bare throughput, and the scrape must parse under a stock Prometheus parser.");
}
