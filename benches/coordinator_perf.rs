//! §Perf L3 bench: coordinator overhead and batching leverage.
//!
//! Measures (a) raw executable step latency per bucket, (b) engine
//! steps/s through the full tick path at the same buckets, so the
//! coordinator's overhead is the gap; (c) end-to-end mixed-workload
//! throughput vs max_batch — the continuous-batching payoff curve;
//! (d) router shard scaling: aggregate steps/s for the same multi-dataset
//! workload at 1/2/4 shards per dataset — the speedup the sharded
//! coordinator is supposed to buy on a multi-core host, measured rather
//! than asserted; (e) per-update-kernel engine throughput (DDIM vs
//! PF-ODE vs AB2 host integration) at a fixed batch; (f) an
//! off-bucket active-lane sweep crossing {old single-bucket policy,
//! occupancy planner} × {pipeline depth 1, 2} — occupancy is asserted
//! (it is deterministic), throughput is recorded; and (g) the sample
//! cache: a cold vs Zipf-hot workload sweep at cache off/on — the hot
//! replay is deterministic, so a nonzero hit rate (and the engine-step
//! savings it buys) is asserted, throughput and hit rate are dumped.
//!
//! Besides the human-readable tables, every section is dumped to
//! `BENCH_coordinator.json` so the perf trajectory is tracked across PRs
//! instead of scraped from stdout.
//!
//!     cargo bench --bench coordinator_perf

#[path = "common.rs"]
mod common;

use std::time::Instant;

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::coordinator::{Engine, Router};
use ddim_serve::jobj;
use ddim_serve::json::{self, Value};
use ddim_serve::runtime::{Runtime, StepOutput};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};

const RESULT_PATH: &str = "BENCH_coordinator.json";

fn raw_step_ms(rt: &mut Runtime, ds: &str, bucket: usize, iters: usize) -> f64 {
    let dim = rt.manifest().sample_dim();
    let x = vec![0.1f32; bucket * dim];
    let t = vec![500.0f32; bucket];
    let a_in = vec![0.3f32; bucket];
    let a_out = vec![0.6f32; bucket];
    let sigma = vec![0.0f32; bucket];
    let noise = vec![0.0f32; bucket * dim];
    let mut out = StepOutput::zeros(bucket * dim);
    let exe = rt.executable(ds, bucket).expect("exe");
    // warmup
    exe.run(&x, &t, &a_in, &a_out, &sigma, &noise, &mut out).expect("warm");
    let t0 = Instant::now();
    for _ in 0..iters {
        exe.run(&x, &t, &a_in, &a_out, &sigma, &noise, &mut out).expect("step");
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let ds = "sprites";
    let iters = if common::quick() { 3 } else { 20 };
    let mut sec_raw: Vec<Value> = Vec::new();
    let mut sec_engine: Vec<Value> = Vec::new();
    let mut sec_mixed: Vec<Value> = Vec::new();
    let mut sec_shards: Vec<Value> = Vec::new();
    let mut sec_kernels: Vec<Value> = Vec::new();
    let mut sec_planner: Vec<Value> = Vec::new();

    println!("=== coordinator_perf (a): raw executable latency per bucket ===");
    println!(
        "{:>8} | {:>12} | {:>14} | {:>12}",
        "bucket", "ms/call", "ms/sample-step", "steps/s"
    );
    let buckets = rt.manifest().buckets.clone();
    let mut raw = Vec::new();
    for &b in &buckets {
        let ms = raw_step_ms(&mut rt, ds, b, iters);
        println!(
            "{b:>8} | {ms:>12.2} | {:>14.2} | {:>12.0}",
            ms / b as f64,
            1e3 / ms * b as f64
        );
        sec_raw.push(jobj![
            ("bucket", b),
            ("ms_per_call", ms),
            ("steps_per_s", 1e3 / ms * b as f64),
        ]);
        raw.push(ms);
    }

    println!("\n=== coordinator_perf (b): engine tick path vs raw executable ===");
    println!(
        "{:>10} | {:>14} | {:>14} | {:>10}",
        "max_batch", "engine steps/s", "raw steps/s", "overhead"
    );
    for (i, &b) in buckets.iter().enumerate() {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: b,
            max_lanes: 4 * b,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        // saturate with enough identical lanes to keep the bucket full
        let steps = if common::quick() { 5 } else { 25 };
        for k in 0..4 {
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode: NoiseMode::Eta(0.0),
                    tau: TauKind::Linear,
                    sampler: SamplerKind::Ddim,
                    body: RequestBody::Generate { count: b, seed: k },
                    return_images: false,
                    cache: CacheMode::Use,
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        let engine_sps = m.steps_executed as f64 / wall;
        let raw_sps = 1e3 / raw[i] * b as f64;
        println!(
            "{b:>10} | {engine_sps:>14.0} | {raw_sps:>14.0} | {:>9.1}%",
            (1.0 - engine_sps / raw_sps) * 100.0
        );
        sec_engine.push(jobj![
            ("max_batch", b),
            ("engine_steps_per_s", engine_sps),
            ("raw_steps_per_s", raw_sps),
            ("overhead_frac", 1.0 - engine_sps / raw_sps),
            ("occupancy", m.occupancy()),
        ]);
    }

    println!("\n=== coordinator_perf (c): mixed heterogeneous workload vs max_batch ===");
    println!(
        "{:>10} | {:>10} | {:>12} | {:>10} | {:>10}",
        "max_batch", "wall s", "steps/s", "occupancy", "p95 ms"
    );
    let n_req = if common::quick() { 8 } else { 24 };
    for &b in &buckets {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: b,
            max_lanes: 64,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        // heterogeneous mix: short interactive + long batch + stochastic
        for k in 0..n_req {
            let (steps, mode, count) = match k % 4 {
                0 => (10, NoiseMode::Eta(0.0), 1),
                1 => (20, NoiseMode::Eta(0.0), 4),
                2 => (50, NoiseMode::Eta(0.0), 1),
                _ => (20, NoiseMode::Eta(1.0), 2),
            };
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode,
                    tau: TauKind::Linear,
                    sampler: SamplerKind::Ddim,
                    body: RequestBody::Generate { count, seed: k as u64 },
                    return_images: false,
                    cache: CacheMode::Use,
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        println!(
            "{b:>10} | {wall:>10.2} | {:>12.0} | {:>10.2} | {:>10.0}",
            m.steps_executed as f64 / wall,
            m.occupancy(),
            m.latency_p95_s * 1e3
        );
        sec_mixed.push(jobj![
            ("max_batch", b),
            ("wall_s", wall),
            ("steps_per_s", m.steps_executed as f64 / wall),
            ("occupancy", m.occupancy()),
            ("latency_p50_ms", m.latency_p50_s * 1e3),
            ("latency_p95_ms", m.latency_p95_s * 1e3),
        ]);
    }
    println!("\n=== coordinator_perf (d): router shard scaling (multi-dataset workload) ===");
    // 4 logical request streams cycling over every dataset the artifact
    // bundle ships; each sweep re-runs the identical workload with more
    // shards per dataset. On a 4-core host 1 -> 4 shards should exceed
    // 1.5x aggregate steps/s (acceptance criterion for the refactor).
    let datasets: Vec<String> = rt.manifest().datasets.keys().cloned().collect();
    let streams: Vec<String> =
        (0..4).map(|i| datasets[i % datasets.len()].clone()).collect();
    let n_req = if common::quick() { 8 } else { 32 };
    let steps = if common::quick() { 5 } else { 20 };
    println!(
        "{:>8} | {:>8} | {:>10} | {:>12} | {:>10} | {:>10}",
        "shards", "total", "wall s", "steps/s", "p95 ms", "speedup"
    );
    let mut base_sps = 0.0f64;
    for &shards in &[1usize, 2, 4] {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: streams[0].clone(),
            max_batch: 8,
            max_lanes: 32,
            queue_capacity: 1024,
            shards,
            ..Default::default()
        };
        let router = Router::start(cfg).expect("router");
        // prewarm every pool so bring-up + executable compilation (both
        // scale with shard count) stay out of the timed region
        for ds in datasets.iter() {
            router.prewarm(ds).expect("prewarm");
        }
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(n_req);
        for k in 0..n_req {
            pending.push(router.submit(Request {
                dataset: streams[k % streams.len()].clone(),
                steps,
                mode: if k % 4 == 3 { NoiseMode::Eta(1.0) } else { NoiseMode::Eta(0.0) },
                tau: TauKind::Linear,
                sampler: SamplerKind::Ddim,
                body: RequestBody::Generate { count: 2 + (k % 3), seed: k as u64 },
                return_images: false,
                cache: CacheMode::Use,
            }));
        }
        for rx in pending {
            let resp = rx.recv().expect("response");
            if let ddim_serve::coordinator::ResponseBody::Error { message } = &resp.body {
                panic!("request failed: {message}");
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (agg, per_shard) = router.aggregate();
        let sps = agg.steps_executed as f64 / wall;
        if shards == 1 {
            base_sps = sps;
        }
        println!(
            "{shards:>8} | {:>8} | {wall:>10.2} | {sps:>12.0} | {:>10.0} | {:>9.2}x",
            per_shard.len(),
            agg.latency_p95_s * 1e3,
            if base_sps > 0.0 { sps / base_sps } else { 1.0 }
        );
        sec_shards.push(jobj![
            ("shards_per_dataset", shards),
            ("total_shards", per_shard.len()),
            ("wall_s", wall),
            ("steps_per_s", sps),
            ("latency_p50_ms", agg.latency_p50_s * 1e3),
            ("latency_p95_ms", agg.latency_p95_s * 1e3),
            ("occupancy", agg.occupancy()),
            ("speedup_vs_1", if base_sps > 0.0 { sps / base_sps } else { 1.0 }),
        ]);
        router.shutdown();
    }

    println!("\n=== coordinator_perf (e): per-update-kernel engine throughput ===");
    // same model, same executable calls; the delta is the host-side
    // integration cost of PF-ODE / AB2 vs committing the fused x_prev
    println!(
        "{:>8} | {:>10} | {:>12} | {:>10}",
        "kernel", "wall s", "steps/s", "p95 ms"
    );
    let steps = if common::quick() { 5 } else { 20 };
    let n_req = if common::quick() { 4 } else { 12 };
    for kernel in SamplerKind::ALL {
        let cfg = ServeConfig {
            artifact_root: common::artifacts_root(),
            dataset: ds.into(),
            max_batch: 8,
            max_lanes: 64,
            queue_capacity: 1024,
            ..Default::default()
        };
        let mut engine = Engine::new(cfg).expect("engine");
        engine.warmup().expect("warmup");
        for k in 0..n_req {
            engine
                .submit(Request {
                    dataset: ds.into(),
                    steps,
                    mode: NoiseMode::Eta(0.0),
                    tau: TauKind::Linear,
                    sampler: kernel,
                    body: RequestBody::Generate { count: 2, seed: k },
                    return_images: false,
                    cache: CacheMode::Use,
                })
                .expect("submit");
        }
        let t0 = Instant::now();
        engine.run_until_idle().expect("drain");
        let wall = t0.elapsed().as_secs_f64();
        let m = engine.metrics();
        let sps = m.steps_executed as f64 / wall;
        println!(
            "{:>8} | {wall:>10.2} | {sps:>12.0} | {:>10.0}",
            kernel.label(),
            m.latency_p95_s * 1e3
        );
        assert_eq!(
            m.kernel_steps[kernel.index()],
            m.steps_executed,
            "every step should be accounted to the requested kernel"
        );
        sec_kernels.push(jobj![
            ("kernel", kernel.label()),
            ("wall_s", wall),
            ("steps_per_s", sps),
            ("occupancy", m.occupancy()),
            ("latency_p50_ms", m.latency_p50_s * 1e3),
            ("latency_p95_ms", m.latency_p95_s * 1e3),
        ]);
    }

    println!("\n=== coordinator_perf (f): occupancy planner × pipelined executor ===");
    // Off-bucket active-lane counts (nothing in {1,2,4,8,16} fits 9/17/33
    // exactly) under a mixed-kernel workload, crossing the batch-formation
    // policy (max_padding_waste 1.0 = old single-bucket, 0.25 = planner)
    // with pipeline depth 1 (serial) and 2 (executor thread). Occupancy and
    // padding waste are scheduling arithmetic — deterministic, asserted.
    // Throughput is wall-clock — printed and dumped, not asserted.
    println!(
        "{:>6} | {:>8} | {:>6} | {:>10} | {:>10} | {:>6} | {:>9} | {:>8} | {:>8}",
        "lanes", "policy", "depth", "steps/s", "occupancy", "waste", "sub/tick", "overlap", "speedup"
    );
    let steps = if common::quick() { 4 } else { 12 };
    for &lanes in &[9usize, 17, 33] {
        let mut occ_single = 0.0f64;
        let mut sps_depth1 = 0.0f64;
        for &(policy, waste) in &[("single", 1.0f64), ("planner", 0.25)] {
            for &depth in &[1usize, 2] {
                let cfg = ServeConfig {
                    artifact_root: common::artifacts_root(),
                    dataset: ds.into(),
                    max_batch: lanes,
                    max_lanes: 64,
                    queue_capacity: 1024,
                    max_padding_waste: waste,
                    pipeline_depth: depth,
                    ..Default::default()
                };
                let mut engine = Engine::new(cfg).expect("engine");
                engine.warmup().expect("warmup");
                // mixed-kernel fill: exactly `lanes` equal-length lanes so
                // the active count (and thus the tick plan) stays constant
                let third = lanes / 3;
                for (kernel, count, seed) in [
                    (SamplerKind::Ddim, lanes - 2 * third, 1u64),
                    (SamplerKind::PfOde, third, 2),
                    (SamplerKind::Ab2, third, 3),
                ] {
                    engine
                        .submit(Request {
                            dataset: ds.into(),
                            steps,
                            mode: NoiseMode::Eta(0.0),
                            tau: TauKind::Linear,
                            sampler: kernel,
                            body: RequestBody::Generate { count, seed },
                            return_images: false,
                            cache: CacheMode::Use,
                        })
                        .expect("submit");
                }
                let t0 = Instant::now();
                engine.run_until_idle().expect("drain");
                let wall = t0.elapsed().as_secs_f64();
                let m = engine.metrics();
                let sps = m.steps_executed as f64 / wall;
                assert_eq!(m.steps_executed, (lanes * steps) as u64);
                if policy == "single" && depth == 1 {
                    occ_single = m.occupancy();
                }
                if policy == "planner" && depth == 1 {
                    sps_depth1 = sps;
                    // deterministic scheduling arithmetic: the planner may
                    // never lose occupancy to the single-bucket policy
                    assert!(
                        m.occupancy() + 1e-9 >= occ_single,
                        "planner occupancy {} < single-bucket {occ_single} at {lanes} lanes",
                        m.occupancy()
                    );
                }
                let speedup = if policy == "planner" && depth == 2 && sps_depth1 > 0.0 {
                    sps / sps_depth1
                } else {
                    1.0
                };
                println!(
                    "{lanes:>6} | {policy:>8} | {depth:>6} | {sps:>10.0} | {:>10.2} | {:>6.2} | {:>9.2} | {:>8.2} | {speedup:>7.2}x",
                    m.occupancy(),
                    m.padding_waste(),
                    m.sub_batches_per_tick(),
                    m.overlap_frac(),
                );
                sec_planner.push(jobj![
                    ("active_lanes", lanes),
                    ("policy", policy),
                    ("pipeline_depth", depth),
                    ("wall_s", wall),
                    ("steps_per_s", sps),
                    ("occupancy", m.occupancy()),
                    ("padding_waste", m.padding_waste()),
                    ("sub_batches", m.sub_batches),
                    ("sub_batches_per_tick", m.sub_batches_per_tick()),
                    ("overlap_frac", m.overlap_frac()),
                ]);
            }
        }
    }

    println!("\n=== coordinator_perf (g): sample cache — cold vs Zipf-hot, off vs on ===");
    // A cold workload (every request a unique identity) and a Zipf-hot one
    // (identities drawn from a finite pool, web-traffic skew), each
    // replayed sequentially through a router with the cache off and on.
    // The replay is deterministic per workload seed, so the hit counts are
    // scheduling arithmetic, not timing — asserted, while throughput is
    // recorded. "req steps/s" counts the steps *requested* (cache-served
    // work included); "engine steps/s" counts steps actually executed —
    // the gap is the saved FLOPs.
    println!(
        "{:>10} | {:>6} | {:>10} | {:>13} | {:>14} | {:>9} | {:>6} | {:>6}",
        "workload", "cache", "wall s", "req steps/s", "engine steps/s", "hit rate", "hits", "coal"
    );
    let dim = rt.manifest().sample_dim();
    let n_req = if common::quick() { 64 } else { 192 };
    let mut sec_cache: Vec<Value> = Vec::new();
    for (wl_name, workload) in [
        ("cold", ddim_serve::workload::Workload::standard(ds, 1000.0)),
        ("zipf_hot", ddim_serve::workload::Workload::zipf(ds, 1000.0, dim, 8, 1.1)),
    ] {
        for cache_on in [false, true] {
            let cfg = ServeConfig {
                artifact_root: common::artifacts_root(),
                dataset: ds.into(),
                max_batch: 8,
                max_lanes: 64,
                queue_capacity: 1024,
                cache_enabled: cache_on,
                coalesce_enabled: cache_on,
                ..Default::default()
            };
            let router = Router::start(cfg).expect("router");
            router.prewarm(ds).expect("prewarm");
            let plan = workload.generate(n_req, 42);
            let requested_steps: usize =
                plan.iter().map(|(_, r)| r.steps * r.lane_count()).sum();
            let t0 = Instant::now();
            for (_, req) in plan {
                let resp = router.call(req).expect("response");
                if let ddim_serve::coordinator::ResponseBody::Error { message } = &resp.body {
                    panic!("request failed: {message}");
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let (agg, _) = router.aggregate();
            let cm = router.cache().metrics();
            // deterministic replay: a hot pool of 8 identities over 6
            // classes is pigeonhole-guaranteed to repeat within 64
            // sequential requests — the cache MUST convert those into hits
            if cache_on && wl_name == "zipf_hot" {
                assert!(
                    cm.hits > 0,
                    "Zipf-hot workload with the cache on produced no hits: {cm:?}"
                );
                assert!(
                    agg.steps_executed < requested_steps as u64,
                    "cache hits must save engine steps"
                );
            }
            if !cache_on {
                assert_eq!(cm.hits, 0, "cache off must not hit");
            }
            println!(
                "{wl_name:>10} | {:>6} | {wall:>10.2} | {:>13.0} | {:>14.0} | {:>9.2} | {:>6} | {:>6}",
                if cache_on { "on" } else { "off" },
                requested_steps as f64 / wall,
                agg.steps_executed as f64 / wall,
                cm.hit_rate(),
                cm.hits,
                cm.coalesced_waiters,
            );
            sec_cache.push(jobj![
                ("workload", wl_name),
                ("cache", if cache_on { "on" } else { "off" }),
                ("requests", n_req),
                ("wall_s", wall),
                ("requested_steps_per_s", requested_steps as f64 / wall),
                ("engine_steps_per_s", agg.steps_executed as f64 / wall),
                ("engine_steps_executed", agg.steps_executed),
                ("requested_steps", requested_steps),
                ("hit_rate", cm.hit_rate()),
                ("hits", cm.hits),
                ("misses", cm.misses),
                ("coalesced_waiters", cm.coalesced_waiters),
                ("cache_bytes", cm.bytes),
                ("latency_p50_ms", agg.latency_p50_s * 1e3),
                ("latency_p95_ms", agg.latency_p95_s * 1e3),
            ]);
            router.shutdown();
        }
    }

    let dump = jobj![
        ("bench", "coordinator_perf"),
        ("quick", common::quick()),
        ("raw_latency", Value::Arr(sec_raw)),
        ("engine_vs_raw", Value::Arr(sec_engine)),
        ("mixed_workload", Value::Arr(sec_mixed)),
        ("shard_scaling", Value::Arr(sec_shards)),
        ("update_kernels", Value::Arr(sec_kernels)),
        ("planner_pipeline", Value::Arr(sec_planner)),
        ("cache", Value::Arr(sec_cache)),
    ];
    match std::fs::write(RESULT_PATH, json::to_string(&dump) + "\n") {
        Ok(()) => println!("\nwrote machine-readable results to {RESULT_PATH}"),
        Err(e) => eprintln!("\nWARN: could not write {RESULT_PATH}: {e}"),
    }

    println!("\ninterpretation: overhead column (b) is the coordinator tax (§Perf target < 5%);\ncurve (c) shows continuous batching converting batch capacity into steps/s at near-constant p95;\nsweep (d) is the sharding payoff — aggregate steps/s should scale with shards until cores saturate;\ntable (e) prices the host-side PF-ODE/AB2 integration against the fused DDIM commit;\nsweep (f) shows the planner converting padded FLOPs into occupancy at off-bucket lane counts,\nand depth-2 pipelining overlapping pack/advance with device time (speedup vs planner depth 1);\nsweep (g) shows the sample cache converting repeated identities into served-without-executing\nrequests — the req-vs-engine steps/s gap on the Zipf-hot row is pure saved FLOPs.");
}
