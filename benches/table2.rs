//! Table 2 reproduction: reconstruction error of the encode→decode round
//! trip vs S (paper: CIFAR-10 test set; ours: held-out procedural sprites
//! — fresh seeds never seen in training). The paper's shape: error falls
//! monotonically with S, reaching ~1e-4 by S≈200.
//!
//!     cargo bench --bench table2

#[path = "common.rs"]
mod common;

use ddim_serve::eval::per_dim_mse;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use std::time::Instant;

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let ds = "sprites";
    let n = if common::quick() { 8 } else { 32 };
    let s_values: Vec<usize> =
        if common::quick() { vec![10, 50] } else { vec![5, 10, 20, 50, 100, 200] };

    let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
    // held-out "test set": model-manifold images via a long deterministic
    // trajectory from fresh seeds (paper used real test images with a model
    // trained on the train split; the round-trip property is the same)
    let gen = SamplePlan::generate(rt.alphas(), TauKind::Linear, 100, NoiseMode::Eta(0.0))
        .expect("plan");
    let originals = runner.generate(&mut rt, &gen, n, 0xBEEF).expect("originals");

    println!("=== Table 2: encode->decode per-dim MSE, {n} images (paper: CIFAR-10 test set) ===");
    println!("{:>6} | {:>12} | paper (CIFAR10)", "S", "ours");
    println!("{}", "-".repeat(44));
    let paper: &[(usize, f64)] =
        &[(10, 0.014), (20, 0.0065), (50, 0.0023), (100, 0.0009), (200, 0.0004)];
    let t0 = Instant::now();
    let mut series = Vec::new();
    for &s in &s_values {
        let enc = SamplePlan::encode(rt.alphas(), TauKind::Linear, s).expect("enc");
        let dec = SamplePlan::generate(rt.alphas(), TauKind::Linear, s, NoiseMode::Eta(0.0))
            .expect("dec");
        let latents = runner.run_from(&mut rt, &enc, originals.clone(), 0).expect("encode");
        let recons = runner.run_from(&mut rt, &dec, latents, 0).expect("decode");
        let mse = per_dim_mse(&originals, &recons).expect("mse");
        let paper_v = paper
            .iter()
            .find(|(ps, _)| *ps == s)
            .map(|(_, v)| format!("{v}"))
            .unwrap_or_else(|| "-".into());
        println!("{s:>6} | {mse:>12.6} | {paper_v}");
        series.push(mse);
    }
    let monotone = series.windows(2).all(|w| w[1] <= w[0] * 1.05);
    println!(
        "[{}] error decreases with S (paper's Table-2 shape)",
        if monotone { "PASS" } else { "WARN" }
    );
    println!("table2 done in {:.1}s", t0.elapsed().as_secs_f64());
}
