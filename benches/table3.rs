//! Table 3 reproduction: DDIM (η=0) vs DDPM (η=1) on the LSUN analogues
//! (checker ≈ Bedroom, rings ≈ Church), S ∈ {10, 20, 50, 100}. Paper's
//! shape: DDIM dominates at small S; the gap closes by S=100.
//!
//!     cargo bench --bench table3

#[path = "common.rs"]
mod common;

use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, TauKind};
use std::time::Instant;

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let n = common::cell_n(96);
    let s_values: Vec<usize> =
        if common::quick() { vec![10, 20] } else { vec![10, 20, 50, 100] };
    let datasets = ["checker", "rings"];

    println!("=== Table 3: proxy-FID, {n} samples/cell (paper: LSUN Bedroom + Church) ===");
    let t0 = Instant::now();
    for ds in datasets {
        println!("\n--- {ds} (linear tau, like the paper's LSUN runs) ---");
        let reference = common::reference_for(&rt, ds);
        let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
        common::print_header("S", &s_values);
        let mut rows = Vec::new();
        for (label, mode) in
            [("DDIM e=0", NoiseMode::Eta(0.0)), ("DDPM e=1", NoiseMode::Eta(1.0))]
        {
            let cells: Vec<f64> = s_values
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    common::fid_cell(
                        &mut rt,
                        &mut runner,
                        &reference,
                        TauKind::Linear,
                        s,
                        mode,
                        n,
                        0x7AB3 + i as u64,
                    )
                })
                .collect();
            common::print_row(label, &cells);
            rows.push(cells);
        }
        let ddim_wins_small_s = rows[0][0] < rows[1][0];
        println!(
            "[{}] {ds}: DDIM beats DDPM at S={} (paper's Table-3 shape)",
            if ddim_wins_small_s { "PASS" } else { "WARN" },
            s_values[0]
        );
    }
    println!("\ntable3 done in {:.1}s", t0.elapsed().as_secs_f64());
}
