//! Ablation (Sec. 4.3): DDIM's Eq.-13 update vs the probability-flow-ODE
//! Euler update (Eq. 15) at equal step budgets. The paper: "While the ODEs
//! are equivalent, the sampling procedures are not ... in fewer sampling
//! steps, however, these choices will make a difference" — DDIM takes Euler
//! steps in dσ, PF-Euler in dt. We run both from identical x_T through the
//! same ε-model and report proxy-FID vs S.
//!
//!     cargo bench --bench ablation_pf_ode

#[path = "common.rs"]
mod common;

use ddim_serve::eval::fid_of_images;
use ddim_serve::rng::GaussianSource;
use ddim_serve::runtime::{Runtime, StepOutput};
use ddim_serve::sampler::{ddim_update_host, pf_euler_update, Ab2State};
use ddim_serve::schedule::{tau_subsequence, TauKind};

/// Drive `n` lanes through S steps applying a (possibly stateful, per-lane)
/// host-side update from the executable's eps output (sigma=0, noise=0
/// inside the kernel; its x_prev output is ignored).
fn run_host_update(
    rt: &mut Runtime,
    ds: &str,
    s: usize,
    n: usize,
    seed: u64,
    mut update: impl FnMut(usize, &[f32], &[f32], f64, f64) -> Vec<f32>,
) -> Vec<Vec<f32>> {
    let dim = rt.manifest().sample_dim();
    let bucket = rt.manifest().bucket_for(n.min(4));
    let tau = tau_subsequence(TauKind::Quadratic, s, rt.alphas().t_max()).unwrap();
    let abar: Vec<f64> = (0..=rt.alphas().t_max()).map(|t| rt.alphas().abar(t)).collect();
    let mut g = GaussianSource::seeded(seed);
    let mut lanes: Vec<Vec<f32>> = (0..n).map(|_| g.vec(dim)).collect();
    let zeros_noise = vec![0.0f32; bucket * dim];
    let mut out = StepOutput::zeros(bucket * dim);
    for i in (0..s).rev() {
        let t_cur = tau[i];
        let t_prev = if i == 0 { 0 } else { tau[i - 1] };
        let (a_t, a_p) = (abar[t_cur], abar[t_prev]);
        for chunk in (0..n).collect::<Vec<_>>().chunks(bucket) {
            let mut x = vec![0.0f32; bucket * dim];
            for (slot, &li) in chunk.iter().enumerate() {
                x[slot * dim..(slot + 1) * dim].copy_from_slice(&lanes[li]);
            }
            let t_v = vec![t_cur as f32; bucket];
            let a_in = vec![a_t as f32; bucket];
            let a_out = vec![a_p as f32; bucket];
            let sig = vec![0.0f32; bucket];
            let exe = rt.executable(ds, bucket).unwrap();
            exe.run(&x, &t_v, &a_in, &a_out, &sig, &zeros_noise, &mut out).unwrap();
            for (slot, &li) in chunk.iter().enumerate() {
                let eps = &out.eps[slot * dim..(slot + 1) * dim];
                lanes[li] = update(li, &lanes[li], eps, a_t, a_p);
            }
        }
    }
    lanes
}

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let ds = "sprites";
    let n = common::cell_n(96);
    let s_values: Vec<usize> = if common::quick() { vec![5, 10] } else { vec![5, 10, 20, 50] };
    let reference = common::reference_for(&rt, ds);

    println!("=== ablation (Secs. 4.3 + 7): update-rule choice at equal step budgets, {n} samples/cell ===");
    common::print_header("S", &s_values);
    let mut rows = Vec::new();
    for label in ["DDIM Eq.13", "PF Eq.15", "AB2 (Sec.7)"] {
        let cells: Vec<f64> = s_values
            .iter()
            .map(|&s| {
                let imgs = match label {
                    "PF Eq.15" => run_host_update(&mut rt, ds, s, n, 0xAB1, |_, x, e, at, ap| {
                        pf_euler_update(x, e, at, ap)
                    }),
                    "AB2 (Sec.7)" => {
                        let mut states: Vec<Ab2State> =
                            (0..n).map(|_| Ab2State::new()).collect();
                        run_host_update(&mut rt, ds, s, n, 0xAB1, move |li, x, e, at, ap| {
                            states[li].step(x, e, at, ap)
                        })
                    }
                    _ => run_host_update(&mut rt, ds, s, n, 0xAB1, |_, x, e, at, ap| {
                        ddim_update_host(x, e, at, ap)
                    }),
                };
                fid_of_images(&imgs, &reference).unwrap()
            })
            .collect();
        common::print_row(label, &cells);
        rows.push(cells);
    }
    // sanity: host-side DDIM must track the in-kernel DDIM closely
    let in_kernel: Vec<f64> = s_values
        .iter()
        .map(|&s| {
            let mut runner =
                ddim_serve::sampler::BatchRunner::new(&rt, ds, 4).expect("runner");
            common::fid_cell(
                &mut rt,
                &mut runner,
                &reference,
                TauKind::Quadratic,
                s,
                ddim_serve::schedule::NoiseMode::Eta(0.0),
                n,
                0xAB1,
            )
        })
        .collect();
    common::print_row("kernelDDIM", &in_kernel);
    println!(
        "\n[{}] DDIM <= PF-Euler at the smallest S (paper: dt-Euler is worse in few steps)",
        if rows[0][0] <= rows[1][0] * 1.1 { "PASS" } else { "WARN" }
    );
    println!("[note] kernelDDIM row uses different noise path (prior seeds differ) — compare shape, not bits");
}
