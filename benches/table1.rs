//! Table 1 reproduction: proxy-FID vs dim(τ) × η on the CIFAR10/CelebA
//! analogues (sprites: quadratic τ, like the paper's CIFAR10; blobs:
//! linear τ, like CelebA). Rows η ∈ {0.0, 0.2, 0.5, 1.0, σ̂}; the paper's
//! shape to reproduce: η=0 (DDIM) best at small S, σ̂ catastrophic at
//! small S, everything converging as S grows.
//!
//!     cargo bench --bench table1           # full (~128 samples/cell)
//!     DDIM_BENCH_QUICK=1 cargo bench --bench table1

#[path = "common.rs"]
mod common;

use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, TauKind};
use std::time::Instant;

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let n = common::cell_n(128);
    let s_values = common::s_list();
    let modes: Vec<(String, NoiseMode)> = vec![
        ("eta=0.0".into(), NoiseMode::Eta(0.0)),
        ("eta=0.2".into(), NoiseMode::Eta(0.2)),
        ("eta=0.5".into(), NoiseMode::Eta(0.5)),
        ("eta=1.0".into(), NoiseMode::Eta(1.0)),
        ("sigma_hat".into(), NoiseMode::SigmaHat),
    ];
    let datasets = [("sprites", TauKind::Quadratic), ("blobs", TauKind::Linear)];

    println!("=== Table 1: proxy-FID, {n} samples/cell (paper: CIFAR10 + CelebA, Inception-FID) ===");
    let t0 = Instant::now();
    let mut summary: Vec<(String, Vec<f64>)> = Vec::new();
    for (ds, tau) in datasets {
        println!("\n--- {ds} ({tau:?} tau, paper analogue) ---");
        let reference = common::reference_for(&rt, ds);
        let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
        common::print_header("S", &s_values);
        for (label, mode) in &modes {
            let cells: Vec<f64> = s_values
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    common::fid_cell(
                        &mut rt,
                        &mut runner,
                        &reference,
                        tau,
                        s,
                        *mode,
                        n,
                        0xF1D0 + i as u64,
                    )
                })
                .collect();
            common::print_row(label, &cells);
            summary.push((format!("{ds}/{label}"), cells));
        }
    }

    // paper-shape checks printed as PASS/WARN (not hard assertions: n is
    // small and this is a bench, but the reader should see the claim)
    println!("\n=== shape checks (paper Sec. 5.1) ===");
    for (ds, _) in datasets {
        let row = |m: &str| {
            summary
                .iter()
                .find(|(k, _)| k == &format!("{ds}/{m}"))
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        let ddim = row("eta=0.0");
        let ddpm = row("eta=1.0");
        let hat = row("sigma_hat");
        let check = |name: &str, ok: bool| {
            println!("[{}] {ds}: {name}", if ok { "PASS" } else { "WARN" });
        };
        check("DDIM beats DDPM at smallest S", ddim[0] < ddpm[0]);
        check("sigma_hat collapses at smallest S (worst row)", hat[0] > ddim[0] && hat[0] > ddpm[0]);
        check(
            "DDIM quality improves with S",
            ddim.last().unwrap() < &ddim[0],
        );
        let s_values_f: Vec<usize> = common::s_list();
        // speedup estimate: first S where DDIM is within 20% of its best
        let best = ddim.iter().cloned().fold(f64::INFINITY, f64::min);
        let s_at = s_values_f
            .iter()
            .zip(&ddim)
            .find(|(_, f)| **f <= best * 1.2)
            .map(|(s, _)| *s)
            .unwrap_or(*s_values_f.last().unwrap());
        println!(
            "       {ds}: DDIM within 20% of best FID at S={s_at} -> {}x fewer steps than T=1000",
            1000 / s_at
        );
    }
    println!("\ntable1 done in {:.1}s", t0.elapsed().as_secs_f64());
}
