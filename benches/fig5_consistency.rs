//! Fig. 5 reproduction, quantitative: decode the same x_T with S ∈
//! {5,10,20,50,100}; report the same-x_T vs cross-x_T feature-distance
//! ratio (0 = perfectly consistent, 1 = x_T carries nothing) for DDIM and
//! the DDPM control. Paper's claim: DDIM ratios are small — "most
//! high-level features are similar, regardless of the generative
//! trajectory" — while DDPM's are near 1.
//!
//!     cargo bench --bench fig5_consistency

#[path = "common.rs"]
mod common;

use ddim_serve::eval::consistency_score;
use ddim_serve::rng::GaussianSource;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let n = if common::quick() { 6 } else { 24 };
    let s_values: Vec<usize> =
        if common::quick() { vec![5, 10, 20] } else { vec![5, 10, 20, 50, 100] };
    let dim = rt.manifest().sample_dim();

    println!("=== Fig. 5: same-x_T consistency ratio vs S (reference: S={}) ===", s_values.last().unwrap());
    for ds in ["sprites", "blobs"] {
        let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
        let mut g = GaussianSource::seeded(0x515);
        let latents: Vec<Vec<f32>> = (0..n).map(|_| g.vec(dim)).collect();
        println!("\n--- {ds} ({n} shared latents) ---");
        println!("{:>6} | {:>12} | {:>12}", "S", "DDIM ratio", "DDPM ratio");
        println!("{}", "-".repeat(38));
        let mut ddim_rows = Vec::new();
        let mut ddpm_rows = Vec::new();
        for (rows, mode) in
            [(&mut ddim_rows, NoiseMode::Eta(0.0)), (&mut ddpm_rows, NoiseMode::Eta(1.0))]
        {
            for &s in &s_values {
                let plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, s, mode)
                    .expect("plan");
                rows.push(runner.run_from(&mut rt, &plan, latents.clone(), 7).expect("run"));
            }
        }
        let mut ddim_max: f64 = 0.0;
        let mut ddpm_min = f64::INFINITY;
        for (i, &s) in s_values.iter().enumerate().take(s_values.len() - 1) {
            let (_, _, r_ddim) = consistency_score(&ddim_rows[i], ddim_rows.last().unwrap());
            let (_, _, r_ddpm) = consistency_score(&ddpm_rows[i], ddpm_rows.last().unwrap());
            println!("{s:>6} | {r_ddim:>12.3} | {r_ddpm:>12.3}");
            ddim_max = ddim_max.max(r_ddim);
            ddpm_min = ddpm_min.min(r_ddpm);
        }
        println!(
            "[{}] {ds}: DDIM consistently below DDPM (max DDIM {ddim_max:.3} < min DDPM {ddpm_min:.3})",
            if ddim_max < ddpm_min { "PASS" } else { "WARN" }
        );
    }
}
