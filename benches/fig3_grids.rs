//! Fig. 3 reproduction: qualitative sample grids at dim(τ) ∈ {10, 100} for
//! η ∈ {0, 1, σ̂} on both main datasets — the paper's visual "DDPM degrades
//! fast at 10 steps, σ̂ is noisy, DDIM stays clean". Written as PGM grids
//! under `out/fig3/`, plus a quantitative per-grid noise-energy statistic
//! (feature 20, laplacian energy) that makes the visual claim numeric.
//!
//!     cargo bench --bench fig3_grids

#[path = "common.rs"]
mod common;

use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::stats::extract_features;
use ddim_serve::tensor::{save_pgm, tile_grid};

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let n = if common::quick() { 4 } else { 16 };
    let img = rt.manifest().img;
    let s_values = [10usize, 100];
    let modes = [
        ("ddim", NoiseMode::Eta(0.0)),
        ("ddpm", NoiseMode::Eta(1.0)),
        ("sigma_hat", NoiseMode::SigmaHat),
    ];

    println!("=== Fig. 3: sample grids + laplacian noise energy (higher = noisier) ===");
    for ds in ["sprites", "blobs"] {
        let mut runner = BatchRunner::new(&rt, ds, 4).expect("runner");
        println!("\n--- {ds} ---");
        println!("{:>12} | {:>8} | {:>12}", "mode", "S", "noise energy");
        for (label, mode) in modes {
            for s in s_values {
                let tau = if ds == "sprites" { TauKind::Quadratic } else { TauKind::Linear };
                let plan =
                    SamplePlan::generate(rt.alphas(), tau, s, mode).expect("plan");
                let images = runner.generate(&mut rt, &plan, n, 0xF16).expect("gen");
                let energy: f64 = images
                    .iter()
                    .map(|im| extract_features(im)[20])
                    .sum::<f64>()
                    / n as f64;
                println!("{label:>12} | {s:>8} | {energy:>12.4}");
                let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
                let cols = (n as f64).sqrt().ceil() as usize;
                let rows = n.div_ceil(cols);
                let mut padded = refs.clone();
                let blank = vec![0.0f32; img * img];
                while padded.len() < rows * cols {
                    padded.push(&blank);
                }
                let grid = tile_grid(&padded, rows, cols, img, img).expect("grid");
                let path = format!("out/fig3/{ds}_{label}_s{s}.pgm");
                save_pgm(&path, &grid).expect("save");
            }
        }
        println!("grids -> out/fig3/{ds}_*.pgm");
    }
    println!("\npaper's visual claim, quantified: sigma_hat at S=10 should show much higher noise energy than DDIM at S=10.");
}
