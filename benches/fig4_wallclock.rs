//! Fig. 4 reproduction (left panel): wall-clock time to produce samples is
//! *linear* in dim(τ) — the paper plots hours/50k CIFAR images on a 2080
//! Ti; we plot seconds/1k images on this CPU and fit a line, reporting R².
//! Also prints the implied "time for 50k samples" column to mirror the
//! paper's axis, and per-batch-bucket throughput (the serving knob).
//!
//!     cargo bench --bench fig4_wallclock

#[path = "common.rs"]
mod common;

use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use std::time::Instant;

fn main() {
    let Some(mut rt) = common::require_artifacts() else { return };
    let ds = "sprites";
    let n = if common::quick() { 8 } else { 32 };
    let s_values: Vec<usize> =
        if common::quick() { vec![5, 10] } else { vec![1, 2, 5, 10, 20, 50, 100] };

    let mut runner = BatchRunner::new(&rt, ds, 16).expect("runner");
    // warm up the executable cache so compile time doesn't pollute the fit
    let warm = SamplePlan::generate(rt.alphas(), TauKind::Linear, 1, NoiseMode::Eta(0.0))
        .expect("plan");
    runner.generate(&mut rt, &warm, n, 1).expect("warmup");

    println!("=== Fig. 4: sampling wall-clock vs dim(tau), {n} samples/point, bucket 16 ===");
    println!(
        "{:>6} | {:>12} | {:>14} | {:>16}",
        "S", "seconds", "ms/sample", "scaled: h/50k"
    );
    println!("{}", "-".repeat(60));
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &s in &s_values {
        let plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, s, NoiseMode::Eta(0.0))
            .expect("plan");
        let t0 = Instant::now();
        runner.generate(&mut rt, &plan, n, 0xCAFE + s as u64).expect("generate");
        let secs = t0.elapsed().as_secs_f64();
        let per_sample = secs / n as f64;
        println!(
            "{s:>6} | {secs:>12.3} | {:>14.1} | {:>16.2}",
            per_sample * 1e3,
            per_sample * 50_000.0 / 3600.0
        );
        xs.push(s as f64);
        ys.push(secs);
    }

    // least-squares fit y = a + b x and R^2
    let nn = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / nn;
    let my = ys.iter().sum::<f64>() / nn;
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = 1.0 - ss_res / ss_tot;
    println!("\nlinear fit: t = {a:.3} + {b:.4}*S seconds, R^2 = {r2:.4}");
    println!(
        "[{}] wall-clock is linear in dim(tau) (paper Fig. 4: 'scales linearly')",
        if r2 > 0.995 { "PASS" } else { "WARN" }
    );

    // batching leverage: ms/sample at S=10 across buckets
    println!("\n--- per-bucket throughput (S=10, DDIM) ---");
    println!("{:>8} | {:>12} | {:>12}", "bucket", "ms/sample", "samples/s");
    let plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, 10, NoiseMode::Eta(0.0))
        .expect("plan");
    for &bucket in rt.manifest().buckets.clone().iter() {
        let mut r = BatchRunner::new(&rt, ds, bucket).expect("runner");
        // warm: compile this bucket's executable outside the timed region
        r.generate(&mut rt, &warm, bucket, 2).expect("warm");
        let count = bucket * 2;
        let t0 = Instant::now();
        r.generate(&mut rt, &plan, count, 3).expect("generate");
        let per = t0.elapsed().as_secs_f64() / count as f64;
        println!("{bucket:>8} | {:>12.1} | {:>12.1}", per * 1e3, 1.0 / per);
    }
}
