"""The proxy-FID feature map (24 dims), mirrored EXACTLY in
``rust/src/stats/features.rs`` — both sides are covered by golden tests.

FID's job in the paper's Table 1 is to be a distributional distance that is
sensitive both to blur (missing detail at small S) and to additive noise
(the sigma-hat failure mode). The feature map below sees both:
  dims  0..15  4x4 average-pooled intensities   (layout / low-freq content)
  dim   16     global mean
  dim   17     global std
  dim   18     mean |horizontal gradient|       (edge energy -> blur)
  dim   19     mean |vertical gradient|
  dim   20     mean |4-neighbour laplacian|     (noise energy -> sigma-hat)
  dim   21     high-band energy (x - 3x3 box blur), std
  dim   22     std of row means                 (global structure)
  dim   23     std of column means
"""

from __future__ import annotations

import numpy as np

FEAT_DIM = 24


def extract_features(imgs: np.ndarray) -> np.ndarray:
    """imgs: [N, 1, 16, 16] float32 -> [N, 24] float64 features."""
    x = imgs[:, 0].astype(np.float64)  # [N,16,16]
    n = x.shape[0]
    f = np.zeros((n, FEAT_DIM), np.float64)

    # 4x4 average pooling -> 16 dims
    pooled = x.reshape(n, 4, 4, 4, 4).mean(axis=(2, 4))
    f[:, :16] = pooled.reshape(n, 16)

    f[:, 16] = x.mean(axis=(1, 2))
    f[:, 17] = x.std(axis=(1, 2))

    gx = np.abs(np.diff(x, axis=2))  # [N,16,15]
    gy = np.abs(np.diff(x, axis=1))  # [N,15,16]
    f[:, 18] = gx.mean(axis=(1, 2))
    f[:, 19] = gy.mean(axis=(1, 2))

    lap = np.abs(
        4 * x[:, 1:-1, 1:-1] - x[:, :-2, 1:-1] - x[:, 2:, 1:-1] - x[:, 1:-1, :-2] - x[:, 1:-1, 2:]
    )
    f[:, 20] = lap.mean(axis=(1, 2))

    # 3x3 box blur with edge clamping (same as rust impl: clamp indices)
    pad = np.pad(x, ((0, 0), (1, 1), (1, 1)), mode="edge")
    blur = sum(
        pad[:, i : i + 16, j : j + 16] for i in range(3) for j in range(3)
    ) / 9.0
    f[:, 21] = (x - blur).std(axis=(1, 2))

    f[:, 22] = x.mean(axis=2).std(axis=1)
    f[:, 23] = x.mean(axis=1).std(axis=1)
    return f


def fit_gaussian(feats: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (mean [24], covariance [24,24]) with 1/(n-1) normalisation."""
    mu = feats.mean(axis=0)
    d = feats - mu
    cov = d.T @ d / (feats.shape[0] - 1)
    return mu, cov
