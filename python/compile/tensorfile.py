"""Tensorfile: the dumb-as-possible binary interchange format between the
python build path and the rust runtime (mirrored in rust/src/artifacts/).

``<name>.bin``       raw little-endian f32 (or f64), row-major
``<name>.bin.json``  {"shape": [...], "dtype": "f32"|"f64"}
"""

from __future__ import annotations

import json
import os

import numpy as np

_DTYPES = {"f32": np.float32, "f64": np.float64}


def write_tensor(path: str, arr: np.ndarray) -> None:
    """Write ``arr`` to ``path`` (must end in .bin) + its .json sidecar."""
    assert path.endswith(".bin"), path
    arr = np.ascontiguousarray(arr)
    dtype = {np.dtype(np.float32): "f32", np.dtype(np.float64): "f64"}[arr.dtype]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(arr.astype("<" + arr.dtype.str[1:]).tobytes())
    with open(path + ".json", "w") as f:
        json.dump({"shape": list(arr.shape), "dtype": dtype}, f)


def read_tensor(path: str) -> np.ndarray:
    """Read a tensorfile back (used by the python-side golden self-checks)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    dt = _DTYPES[meta["dtype"]]
    with open(path, "rb") as f:
        arr = np.frombuffer(f.read(), dtype=np.dtype(dt).newbyteorder("<"))
    return arr.reshape(meta["shape"]).astype(dt)
