"""L2: the epsilon-model (a small U-Net, Ho et al.-style) and the fused
``denoise_step`` graph that the rust coordinator serves.

The network follows the paper's architecture recipe scaled to 16x16x1
(DESIGN.md section 2): sinusoidal time embedding -> MLP; ResBlocks with
GroupNorm+SiLU and a time-embedding shift; self-attention at the 8x8
resolution; skip connections across the down/up path. ~120k parameters.

``use_pallas`` switches the GroupNorm/attention/update inner ops between the
L1 Pallas kernels (AOT serving graph) and the pure-jnp references (training,
where interpret-mode trace overhead would dominate). pytest proves the two
are numerically interchangeable.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import attention as attention_pallas
from .kernels.ddim_step import ddim_update as ddim_update_pallas
from .kernels.groupnorm import groupnorm_silu as groupnorm_silu_pallas

IMG = 16
CH = 24  # base channels
CH_MID = 48  # channels at the 8x8 level
TEMB = 48  # time-embedding dim
GROUPS = 8
HEADS = 2

Params = dict[str, Any]


# ---------------------------------------------------------------- init utils
def _conv_init(key, cout, cin, kh, kw, scale=1.0):
    fan_in = cin * kh * kw
    std = scale / np.sqrt(fan_in)
    return {
        "w": jax.random.normal(key, (cout, cin, kh, kw), jnp.float32) * std,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _dense_init(key, cout, cin, scale=1.0):
    std = scale / np.sqrt(cin)
    return {
        "w": jax.random.normal(key, (cin, cout), jnp.float32) * std,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _gn_init(c):
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


def _resblock_init(key, cin, cout):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "gn1": _gn_init(cin),
        "conv1": _conv_init(k1, cout, cin, 3, 3),
        "temb": _dense_init(k2, cout, TEMB),
        "gn2": _gn_init(cout),
        # zero-ish init on the last conv so each block starts near identity
        "conv2": _conv_init(k3, cout, cout, 3, 3, scale=1e-4),
    }
    if cin != cout:
        p["skip"] = _conv_init(k4, cout, cin, 1, 1)
    return p


def _attn_init(key, c):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "gn": _gn_init(c),
        "q": _conv_init(k1, c, c, 1, 1),
        "k": _conv_init(k2, c, c, 1, 1),
        "v": _conv_init(k3, c, c, 1, 1),
        "o": _conv_init(k4, c, c, 1, 1, scale=1e-4),
    }


def init_params(seed: int = 0) -> Params:
    """Initialise all U-Net parameters."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 16)
    return {
        "temb1": _dense_init(keys[0], TEMB, TEMB // 2),
        "temb2": _dense_init(keys[1], TEMB, TEMB),
        "conv_in": _conv_init(keys[2], CH, 1, 3, 3),
        "down1": _resblock_init(keys[3], CH, CH),
        "down_conv": _conv_init(keys[4], CH, CH, 3, 3),  # stride-2 16->8
        "down2": _resblock_init(keys[5], CH, CH_MID),
        "down2_attn": _attn_init(keys[6], CH_MID),
        "mid1": _resblock_init(keys[7], CH_MID, CH_MID),
        "mid_attn": _attn_init(keys[8], CH_MID),
        "mid2": _resblock_init(keys[9], CH_MID, CH_MID),
        "up2": _resblock_init(keys[10], CH_MID + CH_MID, CH_MID),
        "up2_attn": _attn_init(keys[11], CH_MID),
        "up1": _resblock_init(keys[12], CH_MID + CH, CH),
        "gn_out": _gn_init(CH),
        "conv_out": _conv_init(keys[13], 1, CH, 3, 3, scale=1e-4),
    }


def param_count(params: Params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))


# ------------------------------------------------------------------- forward
def _conv(p, x, stride=1):
    return (
        jax.lax.conv_general_dilated(
            x, p["w"], (stride, stride), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )
        + p["b"][None, :, None, None]
    )


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _gn_silu(p, x, use_pallas):
    B, C, H, W = x.shape
    fn = groupnorm_silu_pallas if use_pallas else ref.groupnorm_silu_ref
    return fn(x.reshape(B, C, H * W), p["gamma"], p["beta"], GROUPS).reshape(B, C, H, W)


def _gn(p, x, eps=1e-5):
    # plain GroupNorm (no SiLU) for the attention block's pre-norm
    B, C, H, W = x.shape
    g = x.reshape(B, GROUPS, (C // GROUPS) * H * W)
    mean = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.mean((g - mean) ** 2, axis=-1, keepdims=True)
    xhat = ((g - mean) / jnp.sqrt(var + eps)).reshape(B, C, H, W)
    return xhat * p["gamma"][None, :, None, None] + p["beta"][None, :, None, None]


def _resblock(p, x, temb, use_pallas):
    h = _gn_silu(p["gn1"], x, use_pallas)
    h = _conv(p["conv1"], h)
    h = h + _dense(p["temb"], jax.nn.silu(temb))[:, :, None, None]
    h = _gn_silu(p["gn2"], h, use_pallas)
    h = _conv(p["conv2"], h)
    skip = _conv(p["skip"], x) if "skip" in p else x
    return h + skip


def _attnblock(p, x, use_pallas):
    B, C, H, W = x.shape
    Dh = C // HEADS
    hn = _gn(p["gn"], x)
    q, k, v = _conv(p["q"], hn), _conv(p["k"], hn), _conv(p["v"], hn)

    def heads(t):  # [B,C,H,W] -> [B*HEADS, H*W, Dh]
        return t.reshape(B, HEADS, Dh, H * W).transpose(0, 1, 3, 2).reshape(B * HEADS, H * W, Dh)

    fn = attention_pallas if use_pallas else ref.attention_ref
    o = fn(heads(q), heads(k), heads(v))
    o = o.reshape(B, HEADS, H * W, Dh).transpose(0, 1, 3, 2).reshape(B, C, H, W)
    return x + _conv(p["o"], o)


def time_embedding(t):
    """Sinusoidal embedding of a timestep t in [0, T]. [B] -> [B, TEMB//2]."""
    half = TEMB // 4
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def eps_model(params: Params, x, t, use_pallas: bool = False):
    """epsilon_theta(x_t, t): x [B,1,16,16], t [B] float -> eps [B,1,16,16]."""
    temb = _dense(params["temb2"], jax.nn.silu(_dense(params["temb1"], time_embedding(t))))

    h = _conv(params["conv_in"], x)
    h1 = _resblock(params["down1"], h, temb, use_pallas)  # [B,CH,16,16]
    h = _conv(params["down_conv"], h1, stride=2)  # [B,CH,8,8]
    h2 = _resblock(params["down2"], h, temb, use_pallas)  # [B,CH_MID,8,8]
    h2 = _attnblock(params["down2_attn"], h2, use_pallas)

    m = _resblock(params["mid1"], h2, temb, use_pallas)
    m = _attnblock(params["mid_attn"], m, use_pallas)
    m = _resblock(params["mid2"], m, temb, use_pallas)

    u = _resblock(params["up2"], jnp.concatenate([m, h2], axis=1), temb, use_pallas)
    u = _attnblock(params["up2_attn"], u, use_pallas)
    u = jax.image.resize(u, (u.shape[0], u.shape[1], IMG, IMG), "nearest")
    u = _resblock(params["up1"], jnp.concatenate([u, h1], axis=1), temb, use_pallas)

    out = _gn_silu(params["gn_out"], u, use_pallas)
    return _conv(params["conv_out"], out)


def denoise_step(params: Params, x, t, alpha_t, alpha_prev, sigma, noise, use_pallas: bool = True):
    """The fused serving graph (one executable per batch bucket):
    eps-prediction + generalized DDIM update (Eq. 12), with per-sample
    schedule vectors so heterogeneous trajectories batch together.

    x, noise: [B,1,16,16]; t, alpha_t, alpha_prev, sigma: [B].
    Returns (x_prev, eps, x0_pred), each [B,1,16,16].
    """
    B = x.shape[0]
    eps = eps_model(params, x, t, use_pallas)
    fn = ddim_update_pallas if use_pallas else ref.ddim_update_ref
    x_prev, x0 = fn(
        x.reshape(B, -1), eps.reshape(B, -1), noise.reshape(B, -1), alpha_t, alpha_prev, sigma
    )
    return x_prev.reshape(x.shape), eps, x0.reshape(x.shape)


def make_denoise_step_fn(params: Params, use_pallas: bool = True):
    """Close over trained params -> jit-able fn of runtime inputs only (the
    weights become HLO constants; rust passes only the 6 runtime tensors)."""

    @functools.partial(jax.jit)
    def fn(x, t, alpha_t, alpha_prev, sigma, noise):
        return denoise_step(params, x, t, alpha_t, alpha_prev, sigma, noise, use_pallas)

    return fn
