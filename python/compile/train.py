"""Build-time training of epsilon_theta with the paper's objective:
Eq. (5) with gamma = 1 (the Ho et al. L_simple / the paper's L_1), T = 1000.

Theorem 1 is the whole point: this single model, trained once per dataset,
serves *every* (tau, sigma) generative process the rust coordinator builds.
Optimiser is a hand-rolled Adam (no optax in the image) with an EMA copy of
the weights (Ho et al. practice) — the EMA weights are what get AOT-lowered.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .schedule import alpha_bar_table

LR = 2e-3
BETA1, BETA2, EPS = 0.9, 0.999, 1e-8
EMA_DECAY = 0.995
BATCH = 64


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr):
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: BETA1 * m + (1 - BETA1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: BETA2 * v + (1 - BETA2) * g * g, state["v"], grads)
    bc1 = 1 - BETA1 ** step.astype(jnp.float32)
    bc2 = 1 - BETA2 ** step.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + EPS), params, m, v
    )
    return new_params, {"m": m, "v": v, "step": step}


def loss_fn(params, x0, t, eps):
    """Eq. (5), gamma=1: || eps_theta(sqrt(a) x0 + sqrt(1-a) eps, t) - eps ||^2."""
    abar = jnp.asarray(alpha_bar_table(), jnp.float32)
    a = abar[t][:, None, None, None]
    xt = jnp.sqrt(a) * x0 + jnp.sqrt(1 - a) * eps
    pred = model_mod.eps_model(params, xt, t.astype(jnp.float32), use_pallas=False)
    return jnp.mean((pred - eps) ** 2)


@jax.jit
def train_step(params, opt, ema, key, x0):
    kt, ke = jax.random.split(key)
    t = jax.random.randint(kt, (x0.shape[0],), 1, 1001)
    eps = jax.random.normal(ke, x0.shape, jnp.float32)
    loss, grads = jax.value_and_grad(loss_fn)(params, x0, t, eps)
    params, opt = adam_update(params, grads, opt, LR)
    ema = jax.tree_util.tree_map(lambda e, p: EMA_DECAY * e + (1 - EMA_DECAY) * p, ema, params)
    return params, opt, ema, loss


def train(
    dataset: str,
    steps: int,
    seed: int = 0,
    log_every: int = 200,
    init: Any = None,
) -> tuple[Any, list[float]]:
    """Train on ``dataset`` for ``steps`` Adam steps; returns (ema_params,
    losses). Pass ``init`` (a params tree) to resume from cached weights —
    the optimiser state restarts, which is fine for Adam after warmup."""
    params = init if init is not None else model_mod.init_params(seed)
    print(f"[train:{dataset}] {model_mod.param_count(params)} params, {steps} steps, batch {BATCH}"
          + (" (resume)" if init is not None else ""))
    opt = adam_init(params)
    ema = params
    key = jax.random.PRNGKey(seed + 1)
    # one big procedural pool, sliced per step (cheap, exactly reproducible)
    pool = data_mod.generate(dataset, 8192, seed=seed + 77)
    losses: list[float] = []
    t0 = time.time()
    rng = np.random.default_rng(seed + 3)
    for i in range(steps):
        idx = rng.integers(0, pool.shape[0], BATCH)
        key, sub = jax.random.split(key)
        params, opt, ema, loss = train_step(params, opt, ema, sub, jnp.asarray(pool[idx]))
        if i % log_every == 0 or i == steps - 1:
            l = float(loss)
            losses.append(l)
            print(f"[train:{dataset}] step {i:5d} loss {l:.4f} ({time.time() - t0:.1f}s)")
    return ema, losses


def flatten_params(params, prefix=""):
    """dict tree -> {dotted.name: np.ndarray} for npz caching."""
    out = {}
    for k, v in params.items():
        name = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten_params(v, name + "."))
        else:
            out[name] = np.asarray(v)
    return out


def unflatten_params(flat):
    out: dict[str, Any] = {}
    for name, v in flat.items():
        parts = name.split(".")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(v)
    return out
