"""The AOT pipeline: python runs ONCE here (``make artifacts``), then never
again — the rust binary is self-contained against ``artifacts/``.

Per dataset:
  1. train epsilon_theta (or load the cached weights.npz),
  2. lower the fused ``denoise_step`` (Pallas kernels inside) to HLO *text*
     for every batch bucket B in {1,2,4,8,16},
  3. dump reference feature statistics (proxy-FID target) from 4096 fresh
     procedural images,
  4. dump golden input/output pairs for the rust integration tests.
Plus globally: alphas.json (the schedule table) and manifest.json.

HLO TEXT, not ``.serialize()``: jax>=0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import features as feat_mod
from . import model as model_mod
from . import train as train_mod
from .schedule import T_DEFAULT, dump_alphas_json
from .tensorfile import write_tensor

BUCKETS = (1, 2, 4, 8, 16)
DATASETS_STEPS = {"sprites": 3000, "blobs": 3000, "checker": 1400, "rings": 1400}
REF_N = 4096
GOLDEN_BUCKETS = (1, 4)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the trained weights are closed over as HLO
    # constants and MUST survive the text round trip (default elides them).
    return comp.as_hlo_text(True)


def example_args(B: int):
    img = jax.ShapeDtypeStruct((B, 1, model_mod.IMG, model_mod.IMG), jnp.float32)
    vec = jax.ShapeDtypeStruct((B,), jnp.float32)
    return img, vec, vec, vec, vec, img  # x, t, alpha_t, alpha_prev, sigma, noise


def get_params(ds: str, out_dir: str, steps: int, fast: bool):
    """Train (or load cached) EMA weights for ``ds``; returns params tree.
    If the cache holds fewer trained steps than requested, training resumes
    from the cached weights for the difference."""
    cache = os.path.join(out_dir, ds, "weights.npz")
    meta_path = os.path.join(out_dir, ds, "train_meta.json")
    losses_path = os.path.join(out_dir, ds, "train_losses.json")
    if fast:
        steps = 5
    done, init, losses = 0, None, []
    if os.path.exists(cache):
        init = train_mod.unflatten_params(dict(np.load(cache)))
        losses = json.load(open(losses_path)) if os.path.exists(losses_path) else []
        done = json.load(open(meta_path))["steps"] if os.path.exists(meta_path) else steps
        if done >= steps:
            print(f"[aot:{ds}] cached weights cover {done} >= {steps} steps")
            return init, losses
        print(f"[aot:{ds}] resuming from {done} cached steps -> {steps}")
    params, new_losses = train_mod.train(ds, steps - done, init=init)
    losses = losses + new_losses
    os.makedirs(os.path.dirname(cache), exist_ok=True)
    np.savez(cache, **train_mod.flatten_params(params))
    with open(losses_path, "w") as f:
        json.dump(losses, f)
    with open(meta_path, "w") as f:
        json.dump({"steps": steps}, f)
    return params, losses


def lower_buckets(ds: str, params, out_dir: str) -> list[str]:
    fn = model_mod.make_denoise_step_fn(params, use_pallas=True)
    files = []
    for B in BUCKETS:
        t0 = time.time()
        hlo = to_hlo_text(jax.jit(fn).lower(*example_args(B)))
        rel = f"{ds}/denoise_step_b{B}.hlo.txt"
        path = os.path.join(out_dir, rel)
        with open(path, "w") as f:
            f.write(hlo)
        files.append(rel)
        print(f"[aot:{ds}] b{B}: {len(hlo) / 1e6:.1f} MB HLO in {time.time() - t0:.1f}s")
    return files


def dump_ref_stats(ds: str, out_dir: str, n: int) -> None:
    imgs = data_mod.generate(ds, n, seed=1234)
    feats = feat_mod.extract_features(imgs)
    mu, cov = feat_mod.fit_gaussian(feats)
    write_tensor(os.path.join(out_dir, ds, "ref_mu.bin"), mu)
    write_tensor(os.path.join(out_dir, ds, "ref_cov.bin"), cov)


def dump_goldens(ds: str, params, out_dir: str) -> None:
    """Fixed inputs -> outputs of the *pallas* serving graph, for the rust
    integration tests, plus a feature-extractor golden."""
    fn = model_mod.make_denoise_step_fn(params, use_pallas=True)
    gdir = os.path.join(out_dir, ds, "goldens")
    for B in GOLDEN_BUCKETS:
        key = jax.random.PRNGKey(9000 + B)
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (B, 1, model_mod.IMG, model_mod.IMG), jnp.float32)
        noise = jax.random.normal(ks[1], x.shape, jnp.float32)
        t = jnp.linspace(100.0, 900.0, B)
        a_t = jnp.linspace(0.05, 0.6, B)
        a_p = jnp.sqrt(a_t)  # anything larger than a_t works
        sigma = jnp.linspace(0.0, 0.2, B)
        x_prev, eps, x0 = fn(x, t, a_t, a_p, sigma, noise)
        for name, arr in [
            ("x", x), ("t", t), ("alpha_t", a_t), ("alpha_prev", a_p),
            ("sigma", sigma), ("noise", noise),
            ("x_prev", x_prev), ("eps", eps), ("x0", x0),
        ]:
            write_tensor(os.path.join(gdir, f"b{B}_{name}.bin"), np.asarray(arr))
    # feature golden: 8 procedural images + their features
    imgs = data_mod.generate(ds, 8, seed=4321)
    write_tensor(os.path.join(gdir, "feat_imgs.bin"), imgs)
    write_tensor(os.path.join(gdir, "feat_out.bin"), feat_mod.extract_features(imgs))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifacts directory")
    p.add_argument("--datasets", default=",".join(DATASETS_STEPS))
    p.add_argument("--fast", action="store_true", help="5 train steps (CI smoke)")
    p.add_argument("--ref-n", type=int, default=REF_N)
    args = p.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    fast = args.fast or os.environ.get("DDIM_FAST") == "1"

    dump_alphas_json(os.path.join(out, "alphas.json"))
    datasets = [d for d in args.datasets.split(",") if d]
    manifest: dict = {
        "img": model_mod.IMG,
        "channels": 1,
        "T": T_DEFAULT,
        "buckets": list(BUCKETS),
        "feat_dim": feat_mod.FEAT_DIM,
        "model": {
            "ch": model_mod.CH, "ch_mid": model_mod.CH_MID,
            "temb": model_mod.TEMB, "groups": model_mod.GROUPS,
            "heads": model_mod.HEADS,
        },
        "datasets": {},
    }
    for ds in datasets:
        params, losses = get_params(ds, out, DATASETS_STEPS[ds], fast)
        files = lower_buckets(ds, params, out)
        dump_ref_stats(ds, out, 64 if fast else args.ref_n)
        dump_goldens(ds, params, out)
        manifest["datasets"][ds] = {
            "hlo": files,
            "params": model_mod.param_count(params),
            "final_loss": losses[-1],
            "ref_n": 64 if fast else args.ref_n,
        }
        with open(os.path.join(out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(datasets)} datasets to {out}")


if __name__ == "__main__":
    main()
