"""L1 Pallas kernel: the fused generalized DDIM update (paper Eq. 12) with
*per-sample* schedule scalars.

TPU mapping (DESIGN.md section 3): pure elementwise VPU work, zero MXU. Grid
over the batch; each program holds one D-length row of x/eps/noise in VMEM
(D = 256 floats = 1 KiB/row — three input rows + two output rows ~ 5 KiB of
VMEM per program, far under budget) and its three schedule scalars in (1,1)
blocks. Bandwidth-bound: 5*B*D*4 bytes per call.

interpret=True everywhere — the CPU PJRT client cannot run Mosaic
custom-calls; correctness vs kernels.ref is enforced by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, eps_ref, noise_ref, at_ref, ap_ref, s_ref, xp_ref, x0_ref):
    x = x_ref[...]
    eps = eps_ref[...]
    noise = noise_ref[...]
    a_t = at_ref[0, 0]
    a_p = ap_ref[0, 0]
    s = s_ref[0, 0]

    x0 = (x - jnp.sqrt(1.0 - a_t) * eps) * jax.lax.rsqrt(a_t)
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - a_p - s * s, 0.0))
    x0_ref[...] = x0
    xp_ref[...] = jnp.sqrt(a_p) * x0 + dir_coef * eps + s * noise


@functools.partial(jax.jit, static_argnames=())
def ddim_update(x, eps, noise, alpha_t, alpha_prev, sigma):
    """Pallas version of kernels.ref.ddim_update_ref.

    x, eps, noise: [B, D]; alpha_t, alpha_prev, sigma: [B].
    Returns (x_prev [B, D], x0_pred [B, D]).
    """
    B, D = x.shape
    row = pl.BlockSpec((1, D), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (i, 0))
    out = jax.ShapeDtypeStruct((B, D), x.dtype)
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[row, row, row, scalar, scalar, scalar],
        out_specs=[row, row],
        out_shape=[out, out],
        interpret=True,
    )(x, eps, noise, alpha_t[:, None], alpha_prev[:, None], sigma[:, None])
