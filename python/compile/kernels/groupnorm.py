"""L1 Pallas kernel: fused GroupNorm + SiLU (the U-Net's most frequent
normalization pattern — every ResBlock applies it twice).

TPU mapping: grid over (batch, group); each program reduces one
(C/groups, N) tile in VMEM (mean/variance on the VPU), then applies the
affine + SiLU in the same pass — one HBM read and one write per element
instead of the three passes (norm stats / affine / activation) an unfused
graph would do.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[0, 0]  # [Cg, N]
    mean = jnp.mean(x)
    var = jnp.mean((x - mean) ** 2)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    y = xhat * g_ref[0, 0][:, None] + b_ref[0, 0][:, None]
    o_ref[0, 0] = y / (1.0 + jnp.exp(-y))


@functools.partial(jax.jit, static_argnames=("groups",))
def groupnorm_silu(x, gamma, beta, groups: int, eps: float = 1e-5):
    """Pallas version of kernels.ref.groupnorm_silu_ref.

    x: [B, C, N] (N = H*W), gamma/beta: [C]. C must be divisible by groups.
    """
    B, C, N = x.shape
    assert C % groups == 0, (C, groups)
    Cg = C // groups
    xg = x.reshape(B, groups, Cg, N)
    gg = gamma.reshape(1, groups, Cg)
    bg = beta.reshape(1, groups, Cg)
    tile = pl.BlockSpec((1, 1, Cg, N), lambda b, g: (b, g, 0, 0))
    aff = pl.BlockSpec((1, 1, Cg), lambda b, g: (0, g, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(B, groups),
        in_specs=[tile, aff, aff],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((B, groups, Cg, N), x.dtype),
        interpret=True,
    )(xg, gg, bg)
    return out.reshape(B, C, N)
