# Pure-jnp correctness oracles for every Pallas kernel in this package.
# pytest (python/tests/) asserts kernel == ref to tight tolerances via
# hypothesis sweeps — this is the CORE L1 correctness signal, and these
# same functions are what the training loop uses (trace-time-cheap), while
# the AOT serving graph uses the Pallas versions.

from __future__ import annotations

import jax.numpy as jnp


def ddim_update_ref(x, eps, noise, alpha_t, alpha_prev, sigma):
    """Generalized DDIM/DDPM update, Eq. (12) of the paper, vectorised over a
    batch with *per-sample* schedule scalars.

    x, eps, noise: [B, D] (D = C*H*W flattened)
    alpha_t, alpha_prev, sigma: [B]  (alpha are the paper's cumulative alphas)
    Returns (x_prev [B, D], x0_pred [B, D]).
    """
    a_t = alpha_t[:, None]
    a_p = alpha_prev[:, None]
    s = sigma[:, None]
    x0 = (x - jnp.sqrt(1.0 - a_t) * eps) / jnp.sqrt(a_t)
    # guard: 1 - a_p - s^2 can go epsilon-negative at eta=1 endpoints
    dir_coef = jnp.sqrt(jnp.maximum(1.0 - a_p - s * s, 0.0))
    x_prev = jnp.sqrt(a_p) * x0 + dir_coef * eps + s * noise
    return x_prev, x0


def attention_ref(q, k, v):
    """Plain softmax attention. q,k,v: [B, S, Dh] -> [B, S, Dh]."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bsd,btd->bst", q, k) * scale
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bst,btd->bsd", p, v)


def groupnorm_silu_ref(x, gamma, beta, groups: int, eps: float = 1e-5):
    """Fused GroupNorm + SiLU. x: [B, C, N] (N = H*W), gamma/beta: [C]."""
    B, C, N = x.shape
    g = x.reshape(B, groups, (C // groups) * N)
    mean = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.mean((g - mean) ** 2, axis=-1, keepdims=True)
    xhat = ((g - mean) / jnp.sqrt(var + eps)).reshape(B, C, N)
    y = xhat * gamma[None, :, None] + beta[None, :, None]
    return y * jnp.asarray(1.0, x.dtype) / (1.0 + jnp.exp(-y))
