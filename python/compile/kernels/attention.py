"""L1 Pallas kernel: single-tile softmax attention for the U-Net's 8x8
self-attention block.

Hardware adaptation (DESIGN.md section 3): the CUDA original stages K/V tiles
through shared memory per threadblock; at our sizes (S=64 tokens, Dh<=64) the
entire (Q,K,V) for one batch*head fits in VMEM at once, so the BlockSpec
simply maps one (S,Dh) tile per program — one MXU-shaped q@k^T, a numerically
stable softmax on the VPU, and one p@v. Footprint per program:
3*S*Dh*4 + S*S*4 bytes = 64 KiB at S=64, Dh=64 — comfortably in VMEM, so no
FlashAttention-style streaming/rescaling pass is needed (that machinery buys
nothing below the VMEM cliff and costs extra VPU work).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [S, Dh]
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    # f32 accumulation for the logits regardless of input dtype
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(q.dtype), v, preferred_element_type=jnp.float32).astype(q.dtype)


@jax.jit
def attention(q, k, v):
    """Pallas version of kernels.ref.attention_ref. q,k,v: [B, S, Dh]."""
    B, S, Dh = q.shape
    tile = pl.BlockSpec((1, S, Dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((B, S, Dh), q.dtype),
        interpret=True,
    )(q, k, v)
