"""Diffusion schedule math shared between the build path and (via
``artifacts/alphas.json``) the rust coordinator.

Notation follows the DDIM paper (Song et al., 2021): ``alpha_bar[t]`` is the
paper's alpha_t (the *cumulative* product — what Ho et al. call alpha-bar),
indexed t = 1..T with the convention alpha_bar[0] = 1 (paper's alpha_0 := 1).
"""

from __future__ import annotations

import json

import numpy as np

T_DEFAULT = 1000
BETA_START = 1e-4
BETA_END = 0.02


def alpha_bar_table(T: int = T_DEFAULT) -> np.ndarray:
    """Return alpha_bar[0..T] (length T+1) for the Ho et al. linear-beta
    schedule. Index 0 is the convention alpha_0 = 1."""
    betas = np.linspace(BETA_START, BETA_END, T, dtype=np.float64)
    abar = np.concatenate([[1.0], np.cumprod(1.0 - betas)])
    return abar.astype(np.float64)


def tau_linear(S: int, T: int = T_DEFAULT) -> np.ndarray:
    """Linear sub-sequence tau_i = floor(c*i), i=1..S, with tau_S close to T
    (paper App. D.2)."""
    c = T / S
    tau = np.floor(c * np.arange(1, S + 1)).astype(np.int64)
    return np.clip(tau, 1, T)


def tau_quadratic(S: int, T: int = T_DEFAULT) -> np.ndarray:
    """Quadratic sub-sequence tau_i = floor(c*i^2) with tau_S close to T."""
    c = T / (S * S)
    tau = np.floor(c * np.arange(1, S + 1) ** 2).astype(np.int64)
    return np.clip(tau, 1, T)


def sigma_eta(abar: np.ndarray, tau: np.ndarray, eta: float) -> np.ndarray:
    """Eq. (16): sigma_{tau_i}(eta) for i=1..S, with tau_0 := 0 (alpha_bar=1)."""
    a_cur = abar[tau]
    a_prev = abar[np.concatenate([[0], tau[:-1]])]
    return eta * np.sqrt((1 - a_prev) / (1 - a_cur)) * np.sqrt(1 - a_cur / a_prev)


def sigma_hat(abar: np.ndarray, tau: np.ndarray) -> np.ndarray:
    """App. D.3: the larger DDPM variance sigma-hat = sqrt(1 - a_t/a_{t-1})."""
    a_cur = abar[tau]
    a_prev = abar[np.concatenate([[0], tau[:-1]])]
    return np.sqrt(1 - a_cur / a_prev)


def dump_alphas_json(path: str, T: int = T_DEFAULT) -> None:
    abar = alpha_bar_table(T)
    with open(path, "w") as f:
        json.dump(
            {
                "T": T,
                "beta_start": BETA_START,
                "beta_end": BETA_END,
                "alpha_bar": [float(a) for a in abar],
            },
            f,
        )
