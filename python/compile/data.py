"""Procedural 16x16 grayscale datasets (the paper's CIFAR10 / CelebA / LSUN
substitutes — see DESIGN.md section 2).

Every generator is a pure function of (seed, n): deterministic, unlimited,
and exactly reproducible, which is what lets the rust side hold *reference*
feature statistics that are honestly i.i.d. from the target distribution.
Images are float32 in [-1, 1], shape [n, 1, H, W].
"""

from __future__ import annotations

import numpy as np

IMG = 16
DATASETS = ("sprites", "blobs", "checker", "rings")


def _grid(n: int) -> tuple[np.ndarray, np.ndarray]:
    c = (np.arange(IMG, dtype=np.float64) + 0.5) / IMG  # cell centers in (0,1)
    y, x = np.meshgrid(c, c, indexing="ij")
    return (
        np.broadcast_to(x, (n, IMG, IMG)).copy(),
        np.broadcast_to(y, (n, IMG, IMG)).copy(),
    )


def _soft(d: np.ndarray, k: float = 24.0) -> np.ndarray:
    """Smooth inside/outside indicator from a signed distance (antialiasing)."""
    return 1.0 / (1.0 + np.exp(np.clip(k * d, -30, 30)))


def sprites(n: int, seed: int) -> np.ndarray:
    """CIFAR analogue: one random antialiased sprite (disc / square / cross)
    at a random position, scale and intensity, on a random flat background."""
    rng = np.random.default_rng(seed)
    x, y = _grid(n)
    cx = rng.uniform(0.3, 0.7, (n, 1, 1))
    cy = rng.uniform(0.3, 0.7, (n, 1, 1))
    r = rng.uniform(0.12, 0.3, (n, 1, 1))
    kind = rng.integers(0, 3, (n, 1, 1))
    fg = rng.uniform(0.5, 1.0, (n, 1, 1)) * rng.choice([-1.0, 1.0], (n, 1, 1))
    bg = rng.uniform(-0.25, 0.25, (n, 1, 1))

    dx, dy = np.abs(x - cx), np.abs(y - cy)
    d_disc = np.sqrt((x - cx) ** 2 + (y - cy) ** 2) - r
    d_square = np.maximum(dx, dy) - r
    w = r * 0.38
    d_cross = np.minimum(np.maximum(dx - r, dy - w), np.maximum(dx - w, dy - r))
    d = np.where(kind == 0, d_disc, np.where(kind == 1, d_square, d_cross))
    img = bg + (fg - bg) * _soft(d)
    return np.clip(img, -1, 1).astype(np.float32)[:, None]


def blobs(n: int, seed: int) -> np.ndarray:
    """CelebA analogue: a mirror-symmetric pair of gaussian bumps plus a lower
    central bump — crude 'two eyes + mouth' structure, so the model has real
    global correlations to learn (like face layout)."""
    rng = np.random.default_rng(seed)
    x, y = _grid(n)
    ex = rng.uniform(0.18, 0.32, (n, 1, 1))  # eye offset from center
    ey = rng.uniform(0.3, 0.45, (n, 1, 1))
    es = rng.uniform(0.05, 0.1, (n, 1, 1))
    ea = rng.uniform(0.6, 1.0, (n, 1, 1))
    my = rng.uniform(0.6, 0.78, (n, 1, 1))
    ms = rng.uniform(0.06, 0.14, (n, 1, 1))
    ma = rng.uniform(0.4, 0.9, (n, 1, 1))
    bg = rng.uniform(-0.6, -0.2, (n, 1, 1))

    def bump(cx, cy, s, a):
        return a * np.exp(-(((x - cx) ** 2 + (y - cy) ** 2) / (2 * s * s)))

    img = bg + bump(0.5 - ex, ey, es, ea) + bump(0.5 + ex, ey, es, ea)
    img = img + bump(0.5, my, ms, ma)
    return np.clip(img, -1, 1).astype(np.float32)[:, None]


def checker(n: int, seed: int) -> np.ndarray:
    """LSUN-Bedroom analogue: smooth checkerboard with random period, phase,
    orientation jitter and contrast (repetitive man-made texture)."""
    rng = np.random.default_rng(seed)
    x, y = _grid(n)
    fx = rng.uniform(2.0, 4.5, (n, 1, 1))
    fy = rng.uniform(2.0, 4.5, (n, 1, 1))
    px = rng.uniform(0, 2 * np.pi, (n, 1, 1))
    py = rng.uniform(0, 2 * np.pi, (n, 1, 1))
    rot = rng.uniform(-0.3, 0.3, (n, 1, 1))
    amp = rng.uniform(0.5, 1.0, (n, 1, 1))
    xr = x * np.cos(rot) - y * np.sin(rot)
    yr = x * np.sin(rot) + y * np.cos(rot)
    img = amp * np.sin(2 * np.pi * fx * xr + px) * np.sin(2 * np.pi * fy * yr + py)
    return np.clip(img, -1, 1).astype(np.float32)[:, None]


def rings(n: int, seed: int) -> np.ndarray:
    """LSUN-Church analogue: concentric rings with random center, spatial
    frequency, phase and radial decay (strong long-range radial structure)."""
    rng = np.random.default_rng(seed)
    x, y = _grid(n)
    cx = rng.uniform(0.35, 0.65, (n, 1, 1))
    cy = rng.uniform(0.35, 0.65, (n, 1, 1))
    freq = rng.uniform(3.0, 7.0, (n, 1, 1))
    ph = rng.uniform(0, 2 * np.pi, (n, 1, 1))
    decay = rng.uniform(1.0, 3.5, (n, 1, 1))
    amp = rng.uniform(0.6, 1.0, (n, 1, 1))
    rr = np.sqrt((x - cx) ** 2 + (y - cy) ** 2)
    img = amp * np.cos(2 * np.pi * freq * rr + ph) * np.exp(-decay * rr)
    return np.clip(img, -1, 1).astype(np.float32)[:, None]


_GENS = {"sprites": sprites, "blobs": blobs, "checker": checker, "rings": rings}


def generate(name: str, n: int, seed: int) -> np.ndarray:
    """Generate ``n`` images from dataset ``name`` with the given seed."""
    return _GENS[name](n, seed)
