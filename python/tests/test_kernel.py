"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes / values with hypothesis. This is THE gate on the serving graph's
numerics — the AOT HLO embeds the Pallas versions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.ddim_step import ddim_update
from compile.kernels.groupnorm import groupnorm_silu


# ------------------------------------------------------------- ddim_update
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 16),
    d=st.sampled_from([1, 8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ddim_update_matches_ref(b, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, d), jnp.float32)
    eps = jax.random.normal(ks[1], (b, d), jnp.float32)
    noise = jax.random.normal(ks[2], (b, d), jnp.float32)
    a_t = jax.random.uniform(ks[3], (b,), jnp.float32, 1e-3, 0.999)
    a_p = jnp.minimum(a_t + jax.random.uniform(ks[4], (b,), jnp.float32, 0.0, 0.5), 1.0)
    sigma = jax.random.uniform(ks[5], (b,), jnp.float32, 0.0, 0.3)
    got = ddim_update(x, eps, noise, a_t, a_p, sigma)
    want = ref.ddim_update_ref(x, eps, noise, a_t, a_p, sigma)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-5, atol=2e-5)


def rand(key, shape, lo=-3.0, hi=3.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


def test_ddim_update_eta0_is_deterministic_in_noise():
    """At sigma=0 the noise input must not influence the output (DDIM)."""
    x = rand(0, (4, 256))
    eps = rand(1, (4, 256))
    a_t = jnp.full((4,), 0.3)
    a_p = jnp.full((4,), 0.7)
    sigma = jnp.zeros((4,))
    out1, _ = ddim_update(x, eps, rand(2, (4, 256)), a_t, a_p, sigma)
    out2, _ = ddim_update(x, eps, rand(3, (4, 256)), a_t, a_p, sigma)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_ddim_update_identity_when_alphas_equal():
    """alpha_in == alpha_out and sigma=0 should (nearly) return x: the
    x0-prediction and re-noising cancel."""
    x = rand(0, (2, 64))
    eps = rand(1, (2, 64))
    a = jnp.full((2,), 0.5)
    out, _ = ddim_update(x, eps, jnp.zeros_like(x), a, a, jnp.zeros((2,)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-4, atol=1e-4)


def test_ddim_update_final_step_returns_x0():
    """alpha_out = 1 (the final step): output must equal predicted x0."""
    x = rand(0, (3, 32))
    eps = rand(1, (3, 32))
    a_t = jnp.full((3,), 0.1)
    out, x0 = ddim_update(x, eps, jnp.zeros_like(x), a_t, jnp.ones((3,)), jnp.zeros((3,)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x0), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------- attention
@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 8),
    s=st.sampled_from([4, 16, 64]),
    dh=st.sampled_from([8, 24, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, s, dh, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, dh), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(attention(q, k, v)),
        np.asarray(ref.attention_ref(q, k, v)),
        rtol=2e-5,
        atol=2e-5,
    )


def test_attention_rows_are_convex_combinations():
    """Attention output lies in the convex hull of V rows: bounded by
    min/max of V per feature."""
    q = rand(0, (2, 16, 8), -5, 5)
    k = rand(1, (2, 16, 8), -5, 5)
    v = rand(2, (2, 16, 8))
    out = np.asarray(attention(q, k, v))
    vmin = np.asarray(v).min(axis=1, keepdims=True) - 1e-5
    vmax = np.asarray(v).max(axis=1, keepdims=True) + 1e-5
    assert (out >= vmin).all() and (out <= vmax).all()


def test_attention_large_logits_stable():
    """Softmax stability: huge logits must not produce NaN/inf."""
    q = rand(0, (1, 8, 16), 50.0, 100.0)
    k = rand(1, (1, 8, 16), 50.0, 100.0)
    v = rand(2, (1, 8, 16))
    out = np.asarray(attention(q, k, v))
    assert np.isfinite(out).all()


# ------------------------------------------------------------ groupnorm_silu
@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 8),
    groups=st.sampled_from([1, 2, 8]),
    cg=st.sampled_from([1, 3, 8]),
    n=st.sampled_from([4, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_groupnorm_matches_ref(b, groups, cg, n, seed):
    c = groups * cg
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (b, c, n), jnp.float32) * 2.0
    gamma = jax.random.normal(ks[1], (c,), jnp.float32)
    beta = jax.random.normal(ks[2], (c,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(groupnorm_silu(x, gamma, beta, groups)),
        np.asarray(ref.groupnorm_silu_ref(x, gamma, beta, groups)),
        rtol=3e-5,
        atol=3e-5,
    )


def test_groupnorm_normalizes():
    """With gamma=1, beta=0 the pre-SiLU activations are standardized."""
    x = rand(0, (2, 8, 128), -10, 10)
    gamma = jnp.ones((8,))
    beta = jnp.zeros((8,))
    out = np.asarray(groupnorm_silu(x, gamma, beta, 2))
    xh = np.asarray(x).reshape(2, 2, 4 * 128)
    xh = (xh - xh.mean(-1, keepdims=True)) / np.sqrt(xh.var(-1, keepdims=True) + 1e-5)
    xh = xh.reshape(2, 8, 128)
    want = xh / (1.0 + np.exp(-xh))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_groupnorm_rejects_bad_groups():
    with pytest.raises(AssertionError):
        groupnorm_silu(jnp.zeros((1, 6, 4)), jnp.zeros((6,)), jnp.zeros((6,)), 4)
