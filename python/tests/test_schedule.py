"""Schedule math: the alpha-bar table, tau selection, sigma(eta)/sigma-hat —
including the DDIM<->DDPM special cases the paper calls out (Sec. 4.1)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.schedule import (
    alpha_bar_table,
    sigma_eta,
    sigma_hat,
    tau_linear,
    tau_quadratic,
)


def test_alpha_bar_invariants():
    a = alpha_bar_table(1000)
    assert a[0] == 1.0
    assert np.all(np.diff(a) < 0)
    assert 0 < a[-1] < 1e-4  # prior is essentially N(0, I)


def test_alpha_bar_first_step():
    a = alpha_bar_table(1000)
    assert abs(a[1] - (1 - 1e-4)) < 1e-12


@settings(max_examples=50, deadline=None)
@given(s=st.integers(1, 1000))
def test_tau_shapes(s):
    for tau in (tau_linear(s), tau_quadratic(s)):
        assert len(tau) == s
        assert tau[0] >= 1 and tau[-1] <= 1000
        # linear taus are strictly increasing by construction; quadratic can
        # collide only at tiny s*T corners which the rust side dedups —
        # python only ever uses the documented (S << T) regime
        assert np.all(np.diff(tau_linear(s)) >= 1) or s == 1


def test_tau_full_is_identity():
    assert np.array_equal(tau_linear(1000), np.arange(1, 1001))


def test_sigma_eta1_equals_ddpm_posterior():
    """Eq. 16 at eta=1 must reproduce the DDPM posterior variance
    beta-tilde (paper Sec. 4.1: 'the generative process becomes a DDPM')."""
    abar = alpha_bar_table()
    tau = tau_linear(1000)  # consecutive steps = Markovian case
    s1 = sigma_eta(abar, tau, 1.0)
    a_cur = abar[tau]
    a_prev = abar[np.concatenate([[0], tau[:-1]])]
    beta_tilde = (1 - a_prev) / (1 - a_cur) * (1 - a_cur / a_prev)
    np.testing.assert_allclose(s1**2, beta_tilde, rtol=1e-10)


def test_sigma_zero_and_monotone():
    abar = alpha_bar_table()
    tau = tau_quadratic(20)
    assert np.all(sigma_eta(abar, tau, 0.0) == 0.0)
    last = sigma_eta(abar, tau, 0.0)
    for eta in (0.2, 0.5, 1.0):
        cur = sigma_eta(abar, tau, eta)
        assert np.all(cur >= last)
        last = cur


def test_sigma_hat_dominates():
    abar = alpha_bar_table()
    for s in (10, 50, 100):
        tau = tau_linear(s)
        assert np.all(sigma_hat(abar, tau) >= sigma_eta(abar, tau, 1.0) - 1e-12)
