"""L2 model tests: shapes, pallas/ref interchangeability of the full fused
graph, Lemma-1 marginal preservation, and the Theorem-1 sanity (the training
objective is invariant to sigma)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref
from compile.schedule import alpha_bar_table


@pytest.fixture(scope="module")
def params():
    return M.init_params(seed=3)


def test_param_count_is_reported_scale(params):
    n = M.param_count(params)
    assert 100_000 < n < 1_000_000, n


def test_eps_model_shapes(params):
    for b in (1, 3):
        x = jnp.zeros((b, 1, M.IMG, M.IMG))
        t = jnp.full((b,), 500.0)
        out = M.eps_model(params, x, t)
        assert out.shape == (b, 1, M.IMG, M.IMG)
        assert np.isfinite(np.asarray(out)).all()


def test_eps_model_depends_on_t(params):
    # freshly-initialised nets have near-zero-scaled output convs, so the
    # signal is tiny — compare for exact difference, not allclose
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, M.IMG, M.IMG))
    e1 = np.asarray(M.eps_model(params, x, jnp.array([10.0])))
    e2 = np.asarray(M.eps_model(params, x, jnp.array([900.0])))
    assert np.abs(e1 - e2).max() > 0.0


def test_denoise_step_pallas_equals_ref_graph(params):
    """The serving graph (pallas kernels) must match the pure-jnp graph —
    this is what makes training-with-ref + serving-with-pallas sound."""
    b = 4
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (b, 1, M.IMG, M.IMG), jnp.float32)
    noise = jax.random.normal(ks[1], x.shape, jnp.float32)
    t = jnp.linspace(50.0, 950.0, b)
    a_t = jnp.linspace(0.05, 0.7, b)
    a_p = jnp.sqrt(a_t)
    sigma = jnp.linspace(0.0, 0.2, b)
    got = M.denoise_step(params, x, t, a_t, a_p, sigma, noise, use_pallas=True)
    want = M.denoise_step(params, x, t, a_t, a_p, sigma, noise, use_pallas=False)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-5, atol=5e-5)


def test_time_embedding_distinguishes_timesteps():
    emb = M.time_embedding(jnp.array([1.0, 2.0, 500.0, 1000.0]))
    assert emb.shape == (4, M.TEMB // 2)
    d = np.asarray(emb)
    for i in range(4):
        for j in range(i + 1, 4):
            assert np.linalg.norm(d[i] - d[j]) > 1e-3


def test_lemma1_marginals_preserved():
    """Lemma 1: q_sigma(x_{t-1} | x_0) stays N(sqrt(a) x0, (1-a) I) under the
    non-Markovian posterior — checked by Monte Carlo composition."""
    abar = alpha_bar_table()
    t_cur, t_prev = 600, 400
    a_t, a_p = abar[t_cur], abar[t_prev]
    sigma = 0.3 * np.sqrt(1 - a_p)
    rng = np.random.default_rng(0)
    n = 200_000
    x0 = 0.7
    # sample x_t ~ q(x_t | x_0), then x_{t-1} ~ q_sigma(x_{t-1} | x_t, x_0)
    xt = np.sqrt(a_t) * x0 + np.sqrt(1 - a_t) * rng.standard_normal(n)
    mean = np.sqrt(a_p) * x0 + np.sqrt(1 - a_p - sigma**2) * (xt - np.sqrt(a_t) * x0) / np.sqrt(
        1 - a_t
    )
    xprev = mean + sigma * rng.standard_normal(n)
    # marginal must match N(sqrt(a_p) x0, 1 - a_p)
    assert abs(xprev.mean() - np.sqrt(a_p) * x0) < 5e-3
    assert abs(xprev.var() - (1 - a_p)) < 5e-3


def test_theorem1_objective_invariant_to_sigma(params):
    """Theorem 1 consequence: L_gamma with gamma=1 doesn't reference sigma at
    all — the same eps-prediction loss value serves every sigma. We verify
    the training loss is a pure function of (x0, t, eps), computed through
    the shared eps model."""
    from compile.train import loss_fn

    x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 1, M.IMG, M.IMG), jnp.float32)
    t = jnp.array([100, 200, 300, 400, 500, 600, 700, 800])
    eps = jax.random.normal(jax.random.PRNGKey(1), x0.shape, jnp.float32)
    l1 = loss_fn(params, x0, t, eps)
    l2 = loss_fn(params, x0, t, eps)
    assert float(l1) == float(l2)
    assert float(l1) > 0.0


def test_ddim_update_noise_free_composition(params):
    """Two eta=0 denoise steps compose deterministically end-to-end through
    the real model."""
    b = 2
    x = jax.random.normal(jax.random.PRNGKey(5), (b, 1, M.IMG, M.IMG), jnp.float32)
    abar = alpha_bar_table()
    zeros = jnp.zeros((b,))
    noise = jnp.zeros_like(x)
    xp1, _, _ = M.denoise_step(
        params, x, jnp.full((b,), 800.0),
        jnp.full((b,), abar[800]), jnp.full((b,), abar[400]), zeros, noise)
    xp2, _, _ = M.denoise_step(
        params, x, jnp.full((b,), 800.0),
        jnp.full((b,), abar[800]), jnp.full((b,), abar[400]), zeros, noise)
    np.testing.assert_array_equal(np.asarray(xp1), np.asarray(xp2))
    assert not np.allclose(np.asarray(xp1), np.asarray(x))


def test_ref_update_matches_closed_form():
    """Eq. 12 sanity against a hand-written scalar computation."""
    x = jnp.array([[1.0]])
    eps = jnp.array([[0.5]])
    noise = jnp.array([[2.0]])
    a_t = jnp.array([0.25])
    a_p = jnp.array([0.81])
    s = jnp.array([0.1])
    xp, x0 = ref.ddim_update_ref(x, eps, noise, a_t, a_p, s)
    x0_want = (1.0 - np.sqrt(1 - 0.25) * 0.5) / np.sqrt(0.25)
    xp_want = np.sqrt(0.81) * x0_want + np.sqrt(1 - 0.81 - 0.01) * 0.5 + 0.1 * 2.0
    assert abs(float(x0[0, 0]) - x0_want) < 1e-6
    assert abs(float(xp[0, 0]) - xp_want) < 1e-6
