"""AOT pipeline regression tests — most importantly the constant-elision
guard: jax's default ``as_hlo_text()`` silently drops large constants
(``constant({...``), which once cost us a debugging session of a rust
runtime executing garbage weights."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import example_args, to_hlo_text, BUCKETS, GOLDEN_BUCKETS


def test_hlo_text_keeps_large_constants():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32))

    def fn(x):
        return (x @ w,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 64), jnp.float32))
    text = to_hlo_text(lowered)
    assert "{..." not in text, "large constants were elided from the HLO text"
    assert "f32[64,64]" in text


def test_example_args_shapes():
    for b in BUCKETS:
        x, t, a_t, a_p, sig, noise = example_args(b)
        assert x.shape == (b, 1, 16, 16) and noise.shape == x.shape
        for v in (t, a_t, a_p, sig):
            assert v.shape == (b,)
    assert set(GOLDEN_BUCKETS) <= set(BUCKETS)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_artifacts_are_complete():
    """If `make artifacts` has run, every manifest entry must resolve to
    files with full (non-elided) constants."""
    import json

    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["T"] == 1000
    assert manifest["buckets"] == list(BUCKETS)
    for ds, info in manifest["datasets"].items():
        assert info["final_loss"] < 0.2, f"{ds} undertrained: {info['final_loss']}"
        for rel in info["hlo"]:
            path = os.path.join(root, rel)
            assert os.path.exists(path), path
            # spot-check the head of the file for elision markers
            with open(path) as f:
                head = f.read(200_000)
            assert "{..." not in head, f"{rel} has elided constants"
        for name in ("ref_mu.bin", "ref_cov.bin"):
            assert os.path.exists(os.path.join(root, ds, name))
        assert os.path.exists(os.path.join(root, ds, "goldens", "b1_x.bin"))
