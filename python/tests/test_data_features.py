"""Datasets and the proxy-FID feature map (the python halves of the
cross-language contracts)."""

import numpy as np
import pytest

from compile import data, features
from compile.tensorfile import read_tensor, write_tensor


@pytest.mark.parametrize("name", data.DATASETS)
def test_datasets_shapes_and_range(name):
    imgs = data.generate(name, 32, seed=5)
    assert imgs.shape == (32, 1, 16, 16)
    assert imgs.dtype == np.float32
    assert imgs.min() >= -1.0 and imgs.max() <= 1.0
    # non-degenerate: images differ from each other
    assert np.std(imgs.reshape(32, -1).mean(axis=1)) > 0 or np.std(imgs) > 0.01


@pytest.mark.parametrize("name", data.DATASETS)
def test_datasets_deterministic_per_seed(name):
    a = data.generate(name, 8, seed=1)
    b = data.generate(name, 8, seed=1)
    c = data.generate(name, 8, seed=2)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_features_shape_and_determinism():
    imgs = data.generate("sprites", 16, seed=0)
    f = features.extract_features(imgs)
    assert f.shape == (16, features.FEAT_DIM)
    np.testing.assert_array_equal(f, features.extract_features(imgs))


def test_features_constant_image():
    imgs = np.full((1, 1, 16, 16), 0.25, np.float32)
    f = features.extract_features(imgs)[0]
    np.testing.assert_allclose(f[:17], 0.25, atol=1e-7)
    np.testing.assert_allclose(f[17:], 0.0, atol=1e-7)


def test_features_separate_clean_from_noisy():
    clean = data.generate("sprites", 64, seed=1)
    rng = np.random.default_rng(0)
    noisy = clean + 0.3 * rng.standard_normal(clean.shape).astype(np.float32)
    fc = features.extract_features(clean).mean(axis=0)
    fn = features.extract_features(noisy).mean(axis=0)
    assert fn[20] > fc[20] * 1.5  # laplacian energy jumps under noise
    assert fn[21] > fc[21] * 1.5  # high band too


def test_fit_gaussian_matches_numpy():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((500, features.FEAT_DIM))
    mu, cov = features.fit_gaussian(x)
    np.testing.assert_allclose(mu, x.mean(axis=0), atol=1e-12)
    np.testing.assert_allclose(cov, np.cov(x, rowvar=False), atol=1e-10)


def test_tensorfile_round_trip(tmp_path):
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    p = str(tmp_path / "x.bin")
    write_tensor(p, arr)
    back = read_tensor(p)
    np.testing.assert_array_equal(arr, back)
    arr64 = np.linspace(0, 1, 10)
    p2 = str(tmp_path / "y.bin")
    write_tensor(p2, arr64)
    np.testing.assert_array_equal(arr64, read_tensor(p2))
