//! Offline stand-in for the PJRT/XLA wrapper crate (`xla`).
//!
//! The serving crate's `xla` cargo feature compiles against exactly this
//! API surface. Host-side pieces ([`Literal`]) are genuinely functional so
//! literal-marshalling code and its tests work; device-side pieces
//! ([`PjRtClient`], [`PjRtLoadedExecutable`]) return [`Error::Stub`] at
//! runtime — selecting `--backend xla` on a stub build fails loudly with
//! an actionable message instead of pretending to execute.
//!
//! To deploy on real XLA, override this dependency with a real wrapper
//! exposing the same items, e.g. in the workspace `Cargo.toml`:
//!
//! ```toml
//! [patch.crates-io]            # or a direct path/git override
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```

use std::fmt;

/// Errors surfaced by the wrapper.
#[derive(Debug)]
pub enum Error {
    /// Raised by every device entry point of the stub build.
    Stub(&'static str),
    /// Host-side misuse (shape mismatches in literal marshalling).
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Stub(what) => write!(
                f,
                "xla stub: {what} is unavailable in this build — replace \
                 third_party/xla-stub with a real PJRT wrapper to use --backend xla"
            ),
            Error::Shape(m) => write!(f, "xla literal: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the serving crate uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// Host tensor: fully functional (shape + f32 storage), so marshalling
/// code round-trips for real even on the stub build.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl Literal {
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let PrimitiveType::F32 = ty;
        let n = dims.iter().product();
        Literal { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    pub fn copy_raw_from(&mut self, src: &[f32]) -> Result<()> {
        if src.len() != self.data.len() {
            return Err(Error::Shape(format!(
                "copy_raw_from: {} elements into literal of {}",
                src.len(),
                self.data.len()
            )));
        }
        self.data.copy_from_slice(src);
        Ok(())
    }

    pub fn copy_raw_to(&self, dst: &mut [f32]) -> Result<()> {
        if dst.len() != self.data.len() {
            return Err(Error::Shape(format!(
                "copy_raw_to: literal of {} into {} elements",
                self.data.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(&self.data);
        Ok(())
    }

    /// Decompose a tuple literal. The stub has no device to produce tuple
    /// literals, so this is unreachable in practice and errs defensively.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Stub("Literal::to_tuple (device output decomposition)"))
    }
}

/// Parsed HLO module (device-side: stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (device-side: stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (device-side: stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (device-side: stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (device-side: stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_on_the_host() {
        let mut lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.shape(), &[2, 3]);
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        lit.copy_raw_from(&data).unwrap();
        let mut back = [0.0f32; 6];
        lit.copy_raw_to(&mut back).unwrap();
        assert_eq!(back, data);
        assert!(lit.copy_raw_from(&data[..3]).is_err());
    }

    #[test]
    fn device_entry_points_fail_loudly() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
